"""Serving benchmark: Poisson-arrival load through the continuous-batching
paged engine (DESIGN.md §11), single-model vs k=3 replicated robust decode.

Each row is one cell of a load-mix grid (arrival rate x decode mode x
aggregation rule) at batch >= 64 requests, produced by the ``serve``
topology through ``repro.experiment``'s sweep + scenario-keyed result
cache, and carries its ``ScenarioSpec`` dict as provenance — replay any
row with ``run_experiment(ScenarioSpec.from_dict(row["scenario"]))``.

Reported per cell: p50/p99 end-to-end latency, p50 time-to-first-token,
tokens/sec, completed requests, ejected replicas.  A separate decode-step
microbenchmark (engine occupancy held fixed, jitted step timed directly)
writes ``results/serve_overhead.csv`` — the input to the
``benchmarks.perf_guard`` serve budget (k=3 replicated phocas decode must
stay <= 3.5x a single-replica step).

  python -m benchmarks.run --only serve        # CI smoke
  python -m benchmarks.bench_serve [--full]
"""
from __future__ import annotations

import csv
import dataclasses
import os

ARCH = "granite-8b-reduced"
RULES = ("phocas", "trmean")
K = 3
CACHE_DIR = os.path.join("results", "serve_cache")
OVERHEAD_CSV = os.path.join("results", "serve_overhead.csv")


def _base_spec(full: bool):
    from repro.core.attacks import AttackConfig
    from repro.core.robust import RobustConfig
    from repro.experiment import DataSpec, ModelSpec, ScenarioSpec
    return ScenarioSpec(
        name="serve",
        topology="serve",
        model=ModelSpec(kind="arch", arch=ARCH),
        data=DataSpec(kind="tokens"),
        robust=RobustConfig(rule="phocas", b=(K + 1) // 2 - 1),
        attack=AttackConfig(name="none"),
        topology_params={
            "replicas": 1,
            "max_slots": 8,
            "max_seq_len": 64,
            "block_tokens": 16,
            "num_requests": 128 if full else 64,   # batch >= 64
            "arrival_rate": 1.0,
            "prompt_len": 8,
            "max_new_tokens": 32 if full else 12,
        },
        steps=4000,
        seed=0)


def _row(result) -> dict:
    spec = result.spec
    m = result.final_metrics
    return {
        "mode": ("robust" if spec.topology_params["replicas"] > 1
                 else "single"),
        "rule": (spec.robust.rule
                 if spec.topology_params["replicas"] > 1 else "-"),
        "replicas": spec.topology_params["replicas"],
        "arrival_rate": spec.topology_params["arrival_rate"],
        "batch": spec.topology_params["num_requests"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "ttft_p50_ms": m["ttft_p50_ms"],
        "tokens_per_sec": m["tokens_per_sec"],
        "completed": m["completed"],
        "ejected_replicas": m.get("ejected_replicas", 0.0),
        "scenario": spec.to_dict(),
    }


def _decode_step_overhead(full: bool) -> list:
    """Fixed-occupancy decode-step microbenchmark: single vs k=3 robust
    (per rule), every engine at the same max_slots/table state."""
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import RobustDecoder, ServeEngine, make_replicas

    model = build_model(get_arch(ARCH))
    params = model.init(jax.random.PRNGKey(0))
    iters = 100 if full else 50
    # 32 slots: enough batch that the forward (not dispatch) dominates the
    # single-replica baseline, before the rule's O(B*V) selection passes
    # start to crowd the 3x replica compute at very large batches.
    kw = dict(max_slots=32, max_seq_len=64, block_tokens=16)

    single = ServeEngine(model, params, **kw)
    base_ms = single.time_decode_step(iters=iters)
    rows = [{"mode": "single", "rule": "-", "ms_per_step": base_ms,
             "overhead_vs_single": 1.0}]
    replicas = make_replicas(params, K)
    for rule in RULES:
        eng = ServeEngine(model, replicas, decoder=RobustDecoder(
            rule=rule, k=K), **kw)
        ms = eng.time_decode_step(iters=iters)
        rows.append({"mode": f"{rule}_k{K}", "rule": rule,
                     "ms_per_step": ms,
                     "overhead_vs_single": ms / base_ms})
        print(f"serve decode step {rule}_k{K}: {ms:.2f}ms "
              f"({ms / base_ms:.2f}x single {base_ms:.2f}ms)", flush=True)
    os.makedirs(os.path.dirname(OVERHEAD_CSV), exist_ok=True)
    with open(OVERHEAD_CSV, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows


def main(full: bool = False) -> list:
    from repro.core.attacks import AttackConfig
    from repro.experiment import run_cached, sweep

    base = _base_spec(full)

    # Decode-step overhead first, on a cold process — the perf-guard ratio
    # is sensitive to the thermal/cache state a long grid run leaves behind.
    overhead_rows = _decode_step_overhead(full)

    rates = (0.5, 1.0, 2.0) if full else (0.5, 2.0)   # load mix axis
    axes = {"topology_params.arrival_rate": list(rates)}

    cells = sweep(base, axes)                          # single-model
    robust_base = dataclasses.replace(
        base,
        name="serve-robust",
        topology_params={**base.topology_params, "replicas": K},
        attack=AttackConfig(name="gaussian", num_byzantine=1))
    cells += sweep(robust_base, {"robust.rule": list(RULES), **axes})

    rows = []
    for spec in cells:
        result = run_cached(spec, CACHE_DIR)
        row = _row(result)
        rows.append(row)
        print(f"serve {row['mode']}/{row['rule']}"
              f"/rate{row['arrival_rate']}: "
              f"p50={row['latency_p50_ms']:.0f}ms "
              f"p99={row['latency_p99_ms']:.0f}ms "
              f"{row['tokens_per_sec']:.1f} tok/s", flush=True)

    for r in overhead_rows:
        rows.append({
            "mode": r["mode"], "rule": r["rule"], "replicas":
            1 if r["mode"] == "single" else K, "arrival_rate": 0.0,
            "batch": 0, "latency_p50_ms": 0.0, "latency_p99_ms": 0.0,
            "ttft_p50_ms": 0.0, "tokens_per_sec": 0.0, "completed": 0.0,
            "ejected_replicas": 0.0,
            "ms_per_step": r["ms_per_step"],
            "overhead_vs_single": r["overhead_vs_single"],
            "scenario": base.to_dict()})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(full=args.full)
