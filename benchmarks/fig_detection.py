"""Detection quality sweep (beyond-paper figure): how well the
``repro.defense`` pipeline — rule suspicion scores -> reputation EMA ->
bimodality q̂ — identifies the Byzantine workers, across **every registered
attack × every score-emitting rule**, enumerated from the registry so
plugin rules/attacks (mediam, innerprod, ...) enter the grid automatically.

Per cell: m=20 workers emit synthetic benign gradients (unit mean, paper-
style spread), the attack corrupts the matrix, the rule aggregates with
scores for a few steps while the reputation EMA accumulates, then the
detector's q̂ picks the top-q̂ most-suspicious workers as the predicted
Byzantine set.

Ground truth exists only for *classic* (row-wise) attacks, where the first
q rows are Byzantine — those cells report precision/recall.  Dimensional
attacks (bitflip, gambler) corrupt values at random rows per coordinate, so
no row-level truth exists; those cells report q̂ only (for bitflip the
right answer is a DIFFUSE score vector — every row is partially Byzantine —
so a near-zero q̂ is the honest reading, not a miss).  Adaptive
(step-aware) attacks like slowburn likewise report q̂ only: inside their
trust-building phase the honest reading is q̂ = 0 — evading early
detection is the attack's design, not a detector miss.  An attack="none"
control row per rule measures false positives on clean runs.

Each row records the ``ScenarioSpec`` describing its cell
(``row["scenario"]``), matching the provenance column of the training
benchmarks.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import AttackConfig, RobustConfig, aggregate_matrix, registry
from repro.defense import (DefenseConfig, estimate_q, init_reputation,
                           suspicion_of, update_reputation)
from repro.experiment import DataSpec, ModelSpec, ScenarioSpec

M = 20          # paper: 20 workers
DIM = 128


def run_cell(rule: str, attack: str, q: int, *, m: int = M, d: int = DIM,
             steps: int = 5, seed: int = 0) -> dict:
    """One (rule × attack × q) detection experiment."""
    key = jax.random.PRNGKey(seed)
    b = min(max(q, 2), (m + 1) // 2 - 1)
    cfg = RobustConfig(rule=rule, b=b, q=min(max(q, 1), m - 3),
                       attack=AttackConfig(name=attack, num_byzantine=q))
    spec = ScenarioSpec(
        name=f"detection-{rule}-{attack}-q{q}",
        model=ModelSpec(kind="mlp", dims=(d, 128, 128, 10)),
        data=DataSpec(kind="classification", dim=d, seed=seed),
        robust=RobustConfig(rule=rule, b=b, q=min(max(q, 1), m - 3)),
        attack=AttackConfig(name=attack, num_byzantine=q),
        defense=DefenseConfig(), num_workers=m, steps=steps, seed=seed)
    dcfg = DefenseConfig()
    state = init_reputation(m)
    q_hat = 0
    for t in range(steps):
        k1, k2 = jax.random.split(jax.random.fold_in(key, t))
        u = 1.0 + 0.1 * jax.random.normal(k1, (m, d))   # benign: unit mean
        _, scores = aggregate_matrix(u, cfg, key=k2, with_scores=True,
                                     step=t)
        state = update_reputation(state, scores, dcfg)
        q_hat = int(estimate_q(scores, min_gap=dcfg.detector_min_gap))
    susp = np.asarray(suspicion_of(state))
    pred = set(np.argsort(-susp)[:q_hat].tolist())
    kind = (registry.get_attack_spec(attack).kind
            if attack != "none" else "control")
    row = {"attack": attack, "kind": kind, "rule": rule, "q": q,
           "q_hat": q_hat, "precision": None, "recall": None,
           "scenario": spec.to_dict()}
    if attack == "none":
        row["precision"] = 1.0 if not pred else 0.0    # false-positive check
    elif kind == "classic":
        truth = set(range(q))
        tp = len(pred & truth)
        row["precision"] = tp / len(pred) if pred else 0.0
        row["recall"] = tp / len(truth)
    return row


def main(full: bool = False) -> list:
    steps = 10 if full else 5
    qs = (2, 4, 8) if full else (2, 8)
    rows = []
    for attack in ("none",) + registry.available_attacks():
        attack_qs = (0,) if attack == "none" else qs
        for rule in registry.score_rules():
            for q in attack_qs:
                rows.append(run_cell(rule, attack, q, steps=steps))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
