"""Shared harness for the paper-reproduction benchmarks (Figures 2-4).

Paper setup: m=20 workers, MLP, lr 0.1, batch 32/worker.  The offline
container substitutes the Gaussian-mixture classification task for MNIST
(DESIGN.md §2) and defaults to reduced dims/steps; --full restores
paper-scale rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.core import AttackConfig, RobustConfig, registry
from repro.data import ClassificationData, make_worker_batches
from repro.models.mlp import build_mlp_model, mlp_accuracy
from repro.models.cnn import build_cnn_model, cnn_topk_accuracy
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step

M = 20                         # paper: 20 worker processes

# Registry-enumerated: every registered rule (plugins included) enters the
# sweeps automatically.
RULES = registry.available_rules()

# One AttackConfig per registered attack, at the Byzantine count the paper's
# experiments use (recorded on the attack's registry spec).
ATTACKS: Dict[str, AttackConfig] = {
    "none": AttackConfig(name="none"),
    **{name: AttackConfig(name=name,
                          num_byzantine=registry.get_attack_spec(name).paper_q)
       for name in registry.available_attacks()},
}


def paper_b(attack: str, *, dimensional: int = 8, classic: int = 6) -> int:
    """The paper's trim/Byzantine-estimate parameter per attack kind."""
    if attack == "none":
        return classic
    kind = registry.get_attack_spec(attack).kind
    return dimensional if kind == "dimensional" else classic


@dataclasses.dataclass
class ExpConfig:
    steps: int = 100
    batch_per_worker: int = 20
    dim: int = 64
    lr: float = 0.1
    b: int = 8                 # paper uses q=b=8 for bitflip/gambler
    eval_every: int = 10
    seed: int = 0
    model: str = "mlp"         # mlp | cnn

    @classmethod
    def paper_scale(cls):
        return cls(steps=500, batch_per_worker=32, dim=784, eval_every=25)


def run_experiment(rule: str, attack: str, cfg: Optional[ExpConfig] = None,
                   *, b: Optional[int] = None, verbose: bool = False) -> dict:
    """Train under (rule × attack); returns accuracy curve + final/max acc."""
    cfg = cfg or ExpConfig()
    b = cfg.b if b is None else b
    if cfg.model == "cnn":
        size = 16
        data = ClassificationData(num_classes=10, dim=size * size * 3,
                                  noise=1.0, seed=cfg.seed)
        model = build_cnn_model(in_ch=3, size=size)
        reshape = lambda x: x.reshape(-1, size, size, 3)
        acc_fn = lambda p, t: cnn_topk_accuracy(
            p, {"x": reshape(t["x"]), "y": t["y"]}, k=3)
    else:
        data = ClassificationData(num_classes=10, dim=cfg.dim, noise=0.8,
                                  seed=cfg.seed)
        model = build_mlp_model(dims=(cfg.dim, 128, 128, 10))
        reshape = lambda x: x
        acc_fn = mlp_accuracy

    params = model.init(jax.random.PRNGKey(cfg.seed))
    opt_cfg = OptConfig(name="sgd", lr=cfg.lr)
    m_eff = M
    # Krum-family assumption needs m - q - 2 > 0; paper caps q at 8 for m=20
    q = min(b, M - 3)
    rob = RobustConfig(rule=rule, b=min(b, (M + 1) // 2 - 1), q=q,
                       attack=ATTACKS[attack])
    step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                           num_workers=m_eff, mesh=None, donate=False)
    opt_state = init_opt_state(opt_cfg, params)
    test = data.test_set(1024)
    if cfg.model == "cnn":
        pass

    key = jax.random.PRNGKey(cfg.seed + 1)
    curve = []
    for i in range(cfg.steps):
        raw = data.batch(i, cfg.batch_per_worker * m_eff)
        batch = make_worker_batches(
            {"x": reshape(raw["x"]), "y": raw["y"]}, m_eff)
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jax.random.fold_in(key, i))
        if i % cfg.eval_every == 0 or i == cfg.steps - 1:
            acc = float(acc_fn(params, test))
            curve.append((i, acc))
            if verbose:
                print(f"  {rule}/{attack} step {i}: acc {acc:.4f}",
                      flush=True)
    accs = [a for _, a in curve]
    return {"rule": rule, "attack": attack, "curve": curve,
            "final_acc": accs[-1], "max_acc": max(accs)}
