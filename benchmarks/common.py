"""Shared harness for the paper-reproduction benchmarks (Figures 2-4).

Paper setup: m=20 workers, MLP, lr 0.1, batch 32/worker.  The offline
container substitutes the Gaussian-mixture classification task for MNIST
(DESIGN.md §2) and defaults to reduced dims/steps; --full restores
paper-scale rounds.

Every benchmark cell is a declarative ``repro.experiment.ScenarioSpec``
(:func:`scenario_for`) executed through the single ``run_experiment`` entry
point, and every result row records the spec that produced it
(``row["scenario"]``) — the provenance column ``benchmarks/run.py``
persists into the ``BENCH_*.json`` artifacts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro import experiment
from repro.core import AttackConfig, RobustConfig, registry
from repro.experiment import DataSpec, ModelSpec, ScenarioSpec

M = 20                         # paper: 20 worker processes

# Registry-enumerated: every registered rule (plugins included) enters the
# sweeps automatically.
RULES = registry.available_rules()

# One AttackConfig per registered attack, at the Byzantine count the paper's
# experiments use (recorded on the attack's registry spec).
ATTACKS: Dict[str, AttackConfig] = {
    "none": AttackConfig(name="none"),
    **{name: AttackConfig(name=name,
                          num_byzantine=registry.get_attack_spec(name).paper_q)
       for name in registry.available_attacks()},
}


def paper_b(attack: str, *, dimensional: int = 8, classic: int = 6) -> int:
    """The paper's trim/Byzantine-estimate parameter per attack kind."""
    if attack == "none":
        return classic
    kind = registry.get_attack_spec(attack).kind
    return dimensional if kind == "dimensional" else classic


@dataclasses.dataclass
class ExpConfig:
    steps: int = 100
    batch_per_worker: int = 20
    dim: int = 64
    lr: float = 0.1
    b: int = 8                 # paper uses q=b=8 for bitflip/gambler
    eval_every: int = 10
    seed: int = 0
    model: str = "mlp"         # mlp | cnn

    @classmethod
    def paper_scale(cls):
        return cls(steps=500, batch_per_worker=32, dim=784, eval_every=25)


def scenario_for(rule: str, attack: str, cfg: Optional[ExpConfig] = None,
                 *, b: Optional[int] = None,
                 topology: str = "sync_ps") -> ScenarioSpec:
    """The (rule × attack) benchmark cell as a declarative ScenarioSpec."""
    cfg = cfg or ExpConfig()
    b = cfg.b if b is None else b
    if cfg.model == "cnn":
        size = 16
        model = ModelSpec(kind="cnn", cnn_size=size, cnn_channels=3)
        data = DataSpec(kind="classification", dim=size * size * 3,
                        num_classes=10, noise=1.0, seed=cfg.seed,
                        batch_per_worker=cfg.batch_per_worker)
    else:
        model = ModelSpec(kind="mlp", dims=(cfg.dim, 128, 128, 10))
        data = DataSpec(kind="classification", dim=cfg.dim, num_classes=10,
                        noise=0.8, seed=cfg.seed,
                        batch_per_worker=cfg.batch_per_worker)
    # Krum-family assumption needs m - q - 2 > 0; paper caps q at 8 for m=20
    q = min(b, M - 3)
    from repro.optim import OptConfig
    return ScenarioSpec(
        name=f"{topology}-{rule}-{attack}-b{b}",
        topology=topology,
        model=model,
        data=data,
        robust=RobustConfig(rule=rule, b=min(b, (M + 1) // 2 - 1), q=q),
        attack=ATTACKS[attack],
        opt=OptConfig(name="sgd", lr=cfg.lr),
        num_workers=M,
        steps=cfg.steps,
        seed=cfg.seed,
        log_every=cfg.eval_every,
    )


def run_experiment(rule: str, attack: str, cfg: Optional[ExpConfig] = None,
                   *, b: Optional[int] = None, verbose: bool = False) -> dict:
    """Train under (rule × attack); returns accuracy curve + final/max acc
    + the ``scenario`` dict that produced the row (spec provenance)."""
    spec = scenario_for(rule, attack, cfg, b=b)
    result = experiment.run_experiment(spec, verbose=verbose)
    curve = result.eval_curve
    accs = [a for _, a in curve]
    return {"rule": rule, "attack": attack, "curve": curve,
            "final_acc": accs[-1], "max_acc": max(accs),
            "scenario": spec.to_dict()}
