"""Figure 3 reproduction: sensitivity to the estimated Byzantine count.
(a) bitflip final accuracy vs q for the vector-wise (selection) rules;
(b) gambler max accuracy vs b for every robust rule.  Both panels enumerate
their rule sets from the registry.  CSV: results/fig3.csv."""
from __future__ import annotations

import argparse
import csv
import os

from repro.core import registry

from benchmarks.common import ExpConfig, run_experiment


def main(full: bool = False, out: str = "results/fig3.csv") -> list:
    cfg = ExpConfig.paper_scale() if full else ExpConfig()
    rows = []
    # (a) q-consuming (Krum-family) rules vs q under bitflip — should stay
    # stuck regardless of q; phocas rides along as the dimensional reference
    panel_a = tuple(r for r in registry.available_rules()
                    if registry.get_rule(r).uses_q) + ("phocas",)
    for q in (2, 4, 6, 8):
        for rule in panel_a:
            r = run_experiment(rule, "bitflip", cfg, b=q)
            rows.append({"panel": "a_bitflip", "rule": rule, "b_or_q": q,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"],
                         "scenario": r["scenario"]})
            print(f"fig3a q={q} {rule:10s} final={r['final_acc']:.4f}",
                  flush=True)
    # (b) max accuracy under gambler when b varies — every robust rule that
    # actually consumes the swept parameter (run_experiment maps b into q
    # for the Krum family; median/geomedian ignore both and are skipped)
    panel_b = tuple(r for r in registry.robust_rules()
                    if registry.get_rule(r).uses_b
                    or registry.get_rule(r).uses_q)
    for b in (2, 4, 6, 8):
        for rule in panel_b:
            r = run_experiment(rule, "gambler", cfg, b=b)
            rows.append({"panel": "b_gambler", "rule": rule, "b_or_q": b,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"],
                         "scenario": r["scenario"]})
            print(f"fig3b b={b} {rule:10s} max={r['max_acc']:.4f}",
                  flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(full=ap.parse_args().full)
