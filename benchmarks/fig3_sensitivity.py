"""Figure 3 reproduction: sensitivity to the estimated Byzantine count.
(a) bitflip final accuracy vs q for Krum-family; (b) gambler max accuracy
vs b for all rules.  CSV: results/fig3.csv."""
from __future__ import annotations

import argparse
import csv
import os

from benchmarks.common import ExpConfig, run_experiment


def main(full: bool = False, out: str = "results/fig3.csv") -> list:
    cfg = ExpConfig.paper_scale() if full else ExpConfig()
    rows = []
    # (a) Krum-family vs q under bitflip — should stay stuck regardless of q
    for q in (2, 4, 6, 8):
        for rule in ("krum", "multikrum", "phocas"):
            r = run_experiment(rule, "bitflip", cfg, b=q)
            rows.append({"panel": "a_bitflip", "rule": rule, "b_or_q": q,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"]})
            print(f"fig3a q={q} {rule:10s} final={r['final_acc']:.4f}",
                  flush=True)
    # (b) max accuracy under gambler when b varies
    for b in (2, 4, 6, 8):
        for rule in ("trmean", "phocas", "krum", "multikrum"):
            r = run_experiment(rule, "gambler", cfg, b=b)
            rows.append({"panel": "b_gambler", "rule": rule, "b_or_q": b,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"]})
            print(f"fig3b b={b} {rule:10s} max={r['max_acc']:.4f}",
                  flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(full=ap.parse_args().full)
