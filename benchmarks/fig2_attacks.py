"""Figure 2 reproduction: accuracy of each aggregation rule under each
registered attack (+ Mean-without-Byzantine reference).  The rule × attack
grid is enumerated from the registry, so plugin rules/attacks join the sweep
automatically.  CSV: results/fig2.csv."""
from __future__ import annotations

import argparse
import csv
import os

from repro.core import registry

from benchmarks.common import ExpConfig, RULES, paper_b, run_experiment


def main(full: bool = False, model: str = "mlp",
         out: str = "results/fig2.csv") -> list:
    cfg = ExpConfig.paper_scale() if full else ExpConfig()
    cfg.model = model
    rows = []
    # reference: averaging without Byzantine failures
    ref = run_experiment("mean", "none", cfg)
    rows.append({"attack": "none", "rule": "mean_no_byz",
                 "final_acc": ref["final_acc"], "max_acc": ref["max_acc"],
                 "scenario": ref["scenario"]})
    for attack in registry.available_attacks():
        for rule in RULES:
            r = run_experiment(rule, attack, cfg, b=paper_b(attack))
            rows.append({"attack": attack, "rule": rule,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"],
                         "scenario": r["scenario"]})
            print(f"fig2 {attack:10s} {rule:10s} final={r['final_acc']:.4f} "
                  f"max={r['max_acc']:.4f}", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    args = ap.parse_args()
    main(full=args.full, model=args.model)
