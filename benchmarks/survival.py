"""Survival probability under the gambler attack (§5.2, quantified).

The paper argues dimensional resilience wins because the *probability* of
the resilience assumption breaking is far lower.  This benchmark computes
it, closed-form + Monte-Carlo, for the paper's setting (m=20, one attacked
server holding d_s parameters, each value corrupted i.i.d. w.p. p):

  dimensional rules (Trmean/Phocas, tolerate b per dim):
      P(crash/iter) = 1 − (BinomCDF(b; m, p))^{d_s}
  classic rules (Krum-family, tolerate q whole rows):
      row i is Byzantine if ANY of its d_s values is hit:
      P(row) = 1 − (1−p)^{d_s};   P(crash/iter) = P(#rows > q)

CSV: results/survival.csv.  Each row records the training
``ScenarioSpec`` whose crash probability it quantifies (the gambler cell
at that corruption probability, ``row["scenario"]``).
"""
from __future__ import annotations

import csv
import math
import os

import numpy as np

M = 20


def _scenario_row(b: int, p: float) -> dict:
    """The gambler training scenario this survival row quantifies."""
    import dataclasses
    from benchmarks.common import scenario_for, ExpConfig
    spec = scenario_for("trmean", "gambler", ExpConfig(b=b), b=b)
    spec = dataclasses.replace(
        spec, name=f"survival-trmean-gambler-b{b}-p{p}",
        attack=dataclasses.replace(spec.attack, gambler_prob=p))
    return spec.to_dict()


def _binom_pmf(k, n, p):
    return math.comb(n, k) * p**k * (1 - p) ** (n - k)


def _binom_cdf(k, n, p):
    return sum(_binom_pmf(i, n, p) for i in range(0, k + 1))


def crash_prob_dimensional(b: int, d_s: int, p: float, m: int = M) -> float:
    per_dim_ok = _binom_cdf(b, m, p)
    log_ok = d_s * math.log(max(per_dim_ok, 1e-300))
    return max(1.0 - math.exp(log_ok), 0.0)   # clamp float cancellation


def crash_prob_classic(q: int, d_s: int, p: float, m: int = M) -> float:
    p_row = 1.0 - (1.0 - p) ** d_s
    return 1.0 - _binom_cdf(q, m, p_row)


def montecarlo(b: int, q: int, d_s: int, p: float, iters: int = 2000,
               m: int = M, seed: int = 0):
    rng = np.random.default_rng(seed)
    dim_crash = row_crash = 0
    for _ in range(iters):
        hits = rng.random((m, d_s)) < p
        if (hits.sum(0) > b).any():
            dim_crash += 1
        if (hits.any(1).sum()) > q:
            row_crash += 1
    return dim_crash / iters, row_crash / iters


def main(out: str = "results/survival.csv"):
    rows = []
    # paper setting: MLP ~266k params over 20 servers -> d_s ~ 13k;
    # p = 0.05% (paper) and heavier variants
    for d_s in (1_000, 13_000):
        for p in (0.0005, 0.005):
            for b in (4, 8):
                cd = crash_prob_dimensional(b, d_s, p)
                cc = crash_prob_classic(b, d_s, p)
                mc_d, mc_c = montecarlo(b, b, d_s, p)
                rows.append({"d_server": d_s, "p": p, "b_or_q": b,
                             "P_crash_dimensional": cd,
                             "P_crash_classic": cc,
                             "mc_dimensional": mc_d, "mc_classic": mc_c,
                             "scenario": _scenario_row(b, p)})
                print(f"survival d_s={d_s:6d} p={p:.4f} b=q={b}: "
                      f"dimensional {cd:.3e} (mc {mc_d:.3f})  "
                      f"classic {cc:.3e} (mc {mc_c:.3f})", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
