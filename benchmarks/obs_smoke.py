"""Observability smoke benchmark: a short defended run with the obs layer
armed, asserting the exposition snapshot parses and the core series exist.

This is the CI step ISSUE 8 specifies: 20 defended sync-PS steps with
metrics + tracing on, producing

* ``BENCH_obs.jsonl``          — the run's telemetry record stream
* ``BENCH_obs_snapshot.prom``  — the Prometheus-style exposition snapshot

at the repo root (both uploaded as trend artifacts next to
``BENCH_analysis.json``).  The returned rows summarise the core series so
``benchmarks/run.py --only obs`` can trend them per PR.  Any missing
series raises — this is an assertion harness, not a passive dump.
"""
from __future__ import annotations

import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

JSONL_PATH = os.path.join(REPO_ROOT, "BENCH_obs.jsonl")
SNAPSHOT_PATH = os.path.join(REPO_ROOT, "BENCH_obs_snapshot.prom")

# Series the acceptance criteria pin: per-rule aggregation latency
# histogram (span_ms), q̂ / Δ-margin gauges, ejection-capable counters.
CORE_SERIES = ("repro_span_ms", "repro_q_hat", "repro_resilience_margin",
               "repro_steps", "repro_train_loss")


def main(steps: int = 20):
    from repro.core import AttackConfig, RobustConfig
    from repro.defense import DefenseConfig
    from repro.defense.telemetry import read_jsonl
    from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                                  run_experiment)
    from repro.obs import ObsConfig, parse_exposition

    for path in (JSONL_PATH, SNAPSHOT_PATH):
        if os.path.exists(path):
            os.remove(path)

    spec = ScenarioSpec(
        name="obs-smoke", topology="sync_ps",
        model=ModelSpec(kind="mlp"),
        data=DataSpec(kind="classification"),
        robust=RobustConfig(rule="phocas", b=2, q=2),
        attack=AttackConfig(name="gaussian", num_byzantine=2),
        defense=DefenseConfig(),
        num_workers=10, steps=steps, seed=0,
        telemetry_path=JSONL_PATH)
    result = run_experiment(
        spec, obs=ObsConfig(enabled=True, trace=True,
                            metrics_path=SNAPSHOT_PATH))

    records = read_jsonl(JSONL_PATH)
    kinds: dict = {}
    for r in records:
        kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
    assert kinds.get("train", 0) == steps, \
        f"expected {steps} train records, got {kinds}"
    assert kinds.get("span", 0) == steps, \
        f"expected {steps} span records, got {kinds}"

    with open(SNAPSHOT_PATH) as fh:
        families = parse_exposition(fh.read())   # raises on malformed text
    missing = [s for s in CORE_SERIES if s not in families]
    assert not missing, f"snapshot missing core series: {missing}"

    # The per-rule aggregation latency histogram: span_ms labeled with the
    # step span name and the active rule.
    span_rules = {s[1].get("rule") for s in
                  families["repro_span_ms"]["samples"]}
    assert "phocas" in span_rules, span_rules

    count = next(v for n, labels, v in
                 families["repro_span_ms"]["samples"]
                 if n.endswith("_count") and labels.get("rule") == "phocas")
    rows = [{
        "steps": steps,
        "record_kinds": len(kinds),
        "records": len(records),
        "series": len(families),
        "span_observations": int(count),
        "final_loss": result.final_loss,
        "q_hat": next((r["q_hat"] for r in reversed(result.history)
                       if "q_hat" in r), None),
    }]
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
