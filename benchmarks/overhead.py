"""Robustness overhead at transformer scale: wall-time of the full robust
train step per aggregation rule (reduced gemma2, m=8 workers, CPU).

Complements §4.4's complexity table: what does dimensional robustness cost
end-to-end, relative to plain averaging?  CSV: results/overhead.csv."""
from __future__ import annotations

import csv
import os
import time

import jax

from repro.configs import get_arch
from repro.core import RobustConfig, registry
from repro.data import TokenStream, make_worker_batches
from repro.models import build_model
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step

M = 8


def main(out: str = "results/overhead.csv", reps: int = 3):
    cfg = get_arch("gemma2-2b-reduced")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_cfg = OptConfig(name="sgd", lr=0.1)
    ds = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, global_batch=2 * M)
    batch = make_worker_batches(ds.batch(0), M)
    rows = []
    base_us = None
    # mean first: it is the overhead baseline the other rules divide by
    others = tuple(n for n in registry.available_rules() if n != "mean")
    for rule in ("mean",) + others:
        cls = registry.get_rule(rule)
        b = 2 if cls.uses_b else 0
        q = 2 if cls.uses_q else max(b, 1)
        rob = RobustConfig(rule=rule, b=b, q=q)
        step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                               num_workers=M, mesh=None, donate=False)
        opt_state = init_opt_state(opt_cfg, params)
        p, o, _ = step(params, opt_state, batch, key)      # compile + warm
        jax.block_until_ready(jax.tree.leaves(p)[0])
        t0 = time.perf_counter()
        for _ in range(reps):
            p, o, _ = step(params, opt_state, batch, key)
        jax.block_until_ready(jax.tree.leaves(p)[0])
        us = (time.perf_counter() - t0) / reps * 1e6
        if rule == "mean":
            base_us = us
        rows.append({"rule": rule, "us_per_step": us,
                     "overhead_vs_mean": us / base_us})
        print(f"overhead {rule:10s} {us:12,.0f} us/step "
              f"({us / base_us:.2f}x mean)", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
