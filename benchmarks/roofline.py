"""Roofline analysis from the dry-run artifacts (results/dryrun/*.json).

Per (arch × shape × mesh × layout) record, derives the three terms:

  compute term    = dot_FLOPs/device        / 197 TFLOP/s   (bf16 MXU peak)
  memory term     = 2 × write_bytes/device  / 819 GB/s      (HBM; writes ≈
                    half of traffic — reads estimated equal, documented proxy)
  collective term = collective_bytes/device / 50 GB/s       (1 ICI link,
                    conservative: v5e has 4 links but bisection-limited
                    collectives rarely use them independently)

dot_FLOPs / write_bytes / collective_bytes come from the loop-aware HLO
analyzer (launch/hlo_analysis.py) — XLA's own cost_analysis undercounts
scanned layer stacks by ~num_layers×.

MODEL_FLOPS = 6·N·D (train; N = active params for MoE), 2·N·D (prefill/
decode fwd-only).  The useful-compute ratio MODEL_FLOPS / (dot_flops ×
devices) flags remat/redundancy waste.

Outputs results/roofline.csv and a markdown table on stdout.
"""
from __future__ import annotations

import argparse
import csv
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9                      # v5e


_SHAPES = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
           "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def _attn_flops_fwd(cfg, shape_name: str) -> float:
    """Analytic score+AV matmul FLOPs (excluded from 6·N·D), global fwd."""
    S, B = _SHAPES[shape_name]
    decode = shape_name in ("decode_32k", "long_500k")
    if cfg.num_heads == 0:
        return 0.0
    if cfg.use_mla:
        hd_eff = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim
    else:
        hd_eff = 2 * cfg.head_dim
    total = 0.0
    for w in cfg.layer_windows():
        if decode:
            ctx = min(w, S) if w else S
            total += 2 * B * cfg.num_heads * hd_eff * ctx      # 1 new token
        else:
            ctx = min(w, S) if w else S
            avg_ctx = ctx / 2 if (w is None or w >= S) else ctx
            total += 2 * B * S * cfg.num_heads * hd_eff * avg_ctx
    if cfg.is_encdec and not decode:
        F = cfg.encoder_seq_len
        total += (2 * B * F * cfg.num_heads * hd_eff * F        # encoder
                  + 2 * B * S * cfg.num_heads * hd_eff * F      # cross
                  ) * cfg.encoder_layers / max(cfg.num_layers, 1) \
            * max(cfg.num_layers, 1)
    return total


def _model_flops(rec: dict) -> float:
    S, B = _SHAPES[rec["shape"]]
    D = B if rec["shape"] in ("decode_32k", "long_500k") else S * B
    N = rec.get("active_params") or rec.get("total_params") or 0
    train = rec["shape"] == "train_4k"
    mult = 6 if train else 2
    if rec.get("remat") == "full" and train:
        mult = 8                          # +1 recompute fwd
    flops = mult * N * D
    try:
        from repro.configs import get_arch
        attn = _attn_flops_fwd(get_arch(rec["arch"]), rec["shape"])
        flops += attn * (mult / 2)        # same fwd/bwd/remat multiplier
    except Exception:
        pass
    return flops


def derive(rec: dict) -> dict:
    dev = rec["num_devices"]
    compute_s = rec["dot_flops"] / PEAK_FLOPS
    memory_s = 2.0 * rec["write_bytes"] / HBM_BW
    coll_s = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = _model_flops(rec)
    ratio = mf / max(rec["dot_flops"] * dev, 1.0)
    peak_mem = (rec.get("argument_size_in_bytes", 0)
                + rec.get("temp_size_in_bytes", 0))
    lever = {
        "compute": "reduce redundant aggregation compute / raise MXU "
                   "utilization (bigger per-chunk matmuls)",
        "memory": "shrink materialized f32 score/activation buffers "
                  "(bf16 scores, larger fusion, smaller q-chunk)",
        "collective": "cut per-layer TP all-reduces (2D sharding / "
                      "sequence parallelism) or switch robust-agg layout "
                      "replicated->sharded",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "layout": rec["layout"], "rule": rec["rule"],
        "remat": rec.get("remat", ""),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "bound_s": max(terms.values()),
        "model_flops": mf, "hlo_flops_global": rec["dot_flops"] * dev,
        "useful_ratio": ratio,
        "peak_mem_GB": peak_mem / 1e9,
        "fits_hbm": peak_mem <= HBM_PER_CHIP,
        "lever": lever,
    }


def main(indir: str = "results/dryrun", out: str = "results/roofline.csv",
         mesh: str = None, markdown: bool = True) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(indir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(derive(rec))
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"], r["layout"]))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    if rows:
        with open(out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=rows[0].keys())
            w.writeheader()
            w.writerows(rows)
    if markdown and rows:
        hdr = ("| arch | shape | mesh | layout | compute s | memory s | "
               "collective s | dominant | useful | fits |")
        print(hdr)
        print("|" + "---|" * 10)
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['layout']} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                  f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                  f"{'Y' if r['fits_hbm'] else 'N'} |")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--indir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    main(indir=args.indir, mesh=args.mesh)
