"""Trend the static-analysis finding counts per rule id.

``python -m benchmarks.run --only analysis`` runs ``repro.analysis`` over
the same path set CI gates on (``src benchmarks tests``) and persists one
row per rule id — including zero-count rules, so the artifact's shape is
stable and a regression shows up as a count going 0 -> N, not as a new
key appearing.  The raw findings also land as telemetry-compatible JSONL
(``BENCH_analysis_findings.jsonl``) readable by
``repro.defense.telemetry.read_jsonl`` for the same trajectory tooling
that consumes defense telemetry.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> List[Dict]:
    from repro.analysis import RULES, run_analysis
    from repro.analysis.__main__ import write_jsonl

    paths = [os.path.join(REPO_ROOT, p) for p in ("src", "benchmarks", "tests")]
    t0 = time.time()
    findings = run_analysis(paths)
    wall_us = (time.time() - t0) * 1e6

    write_jsonl(findings,
                os.path.join(REPO_ROOT, "BENCH_analysis_findings.jsonl"))

    counts = {rule: 0 for rule in RULES}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return [{
        "rule": rule,
        "severity": RULES[rule][0],
        "count": counts[rule],
        "wall_us": round(wall_us),
        "paths": ["src", "benchmarks", "tests"],
    } for rule in sorted(RULES)]
