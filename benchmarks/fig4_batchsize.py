"""Figure 4 reproduction: no-failure convergence when batch size varies,
lr = 0.1·batchsize/32.  CSV: results/fig4.csv."""
from __future__ import annotations

import argparse
import csv
import os

from benchmarks.common import ExpConfig, run_experiment


def main(full: bool = False, out: str = "results/fig4.csv") -> list:
    rows = []
    for bs in (8, 32, 128):
        cfg = ExpConfig.paper_scale() if full else ExpConfig()
        cfg.batch_per_worker = bs
        cfg.lr = 0.1 * bs / 32.0
        for rule in ("mean", "trmean", "phocas", "krum"):
            r = run_experiment(rule, "none", cfg, b=6)
            rows.append({"batch": bs, "rule": rule,
                         "final_acc": r["final_acc"],
                         "max_acc": r["max_acc"],
                         "scenario": r["scenario"]})
            print(f"fig4 bs={bs:4d} {rule:8s} final={r['final_acc']:.4f}",
                  flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(full=ap.parse_args().full)
