"""Aggregation hot-path scaling: m × d × b × backend × defense-mode sweep
over every registered rule, as machine-readable perf rows.

Three modes per configuration make the fusion win auditable
(``BENCH_agg_scaling.json`` via ``benchmarks/run.py``):

* ``plain``    — ``rule.reduce(u)`` (defense off).
* ``fused``    — ``rule.reduce_gated_with_scores(u, active)`` (defense on:
  raw scores + reputation-gated aggregate through the one fused hook).
* ``composed`` — the registry base-class composition of the same call
  (``reduce_with_scores`` + ``gate_matrix`` + a second ``reduce``), i.e.
  exactly the pre-fusion two-pass defense step.

``fused_vs_composed < 1`` for a rule demonstrates its defense-enabled step
no longer runs the reduction twice; ``fused_vs_plain`` prices the whole
defense loop relative to a defense-off step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.registry import AggregatorRule
from repro.core.selection import gate_matrix

# Pallas kernels on the CPU backend run in interpret mode (a Python loop
# per grid block) — keep those rows tiny so the sweep stays a smoke test.
PALLAS_CPU_D = 2048


def _time_call(fn, *args, reps: int = 3) -> float:
    out = jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    del out
    return (time.perf_counter() - t0) / reps * 1e6


def main(full: bool = False):
    ms = (8, 32) if not full else (8, 16, 32, 64)
    ds = (1 << 14, 1 << 17) if not full else (1 << 14, 1 << 17, 1 << 20)
    bs = (2,) if not full else (1, 2, 4)
    on_cpu = jax.default_backend() == "cpu"
    key = jax.random.PRNGKey(0)
    rows = []
    for rule_name in registry.available_rules():
        cls = registry.get_rule(rule_name)
        backends = ("xla", "pallas") if cls.has_kernel else ("xla",)
        for backend in backends:
            for m in ms:
                for d in ds:
                    if backend == "pallas" and on_cpu and d > PALLAS_CPU_D:
                        continue
                    for b in (bs if cls.uses_b else (0,)):
                        if cls.uses_b and not 1 <= b <= (m + 1) // 2 - 1:
                            continue
                        u = jax.random.normal(
                            jax.random.fold_in(key, m * d + b), (m, d))
                        active = jnp.ones((m,)).at[:max(1, m // 8)].set(0.0)
                        rule = registry.make_rule(
                            rule_name, registry.RuleParams(
                                b=b, q=2 if cls.uses_q else 0,
                                backend=backend))

                        plain = jax.jit(rule.reduce)
                        fused = jax.jit(lambda u_, a_, r=rule:
                                        r.reduce_gated_with_scores(u_, a_))

                        def composed(u_, a_, r=rule):
                            # the registry default = pre-fusion two passes
                            return AggregatorRule. \
                                reduce_sharded_gated_with_scores(
                                    r, u_, a_, ())
                        composed = jax.jit(composed)

                        t_plain = _time_call(plain, u)
                        t_fused = _time_call(fused, u, active)
                        t_comp = _time_call(composed, u, active)
                        rows.append({
                            "rule": rule_name, "backend": backend,
                            "m": m, "d": d, "b": b,
                            "us_plain": t_plain, "us_fused": t_fused,
                            "us_composed": t_comp,
                            "fused_vs_plain": t_fused / t_plain,
                            "fused_vs_composed": t_fused / t_comp,
                        })
                        print(f"agg_scaling {rule_name:10s} {backend:6s} "
                              f"m={m:3d} d={d:8d} b={b} "
                              f"plain={t_plain:10,.0f}us "
                              f"fused={t_fused:10,.0f}us "
                              f"composed={t_comp:10,.0f}us "
                              f"(f/c={t_fused / t_comp:.2f})", flush=True)
    return rows


if __name__ == "__main__":
    main()
