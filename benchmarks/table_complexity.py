"""§4.4 time-complexity table: µs per aggregation call vs (m, d) for every
rule — empirically confirms Trmean/Phocas ≈ O(dm) vs Krum O(dm²).
CSV: results/table_complexity.csv."""
from __future__ import annotations

import argparse
import csv
import os
import time

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg
from repro.kernels import ops as kops


def _timeit(fn, u, reps=5) -> float:
    out = fn(u)
    jax.block_until_ready(out)                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(u)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(out: str = "results/table_complexity.csv", full: bool = False):
    sizes = [(10, 100_000), (20, 100_000), (40, 100_000), (20, 1_000_000)]
    if full:
        sizes += [(80, 100_000), (20, 10_000_000)]
    rules = {
        "mean": lambda u: agg.mean(u),
        "median": lambda u: agg.median(u),
        "trmean_b4": jax.jit(lambda u: agg.trmean(u, 4)),
        "phocas_b4": jax.jit(lambda u: agg.phocas(u, 4)),
        "trmean_kernel": lambda u: kops.trmean(u, 4),
        "phocas_kernel": lambda u: kops.phocas(u, 4),
        "krum_q4": jax.jit(lambda u: agg.krum(u, 4)),
        "multikrum_q4": jax.jit(lambda u: agg.multikrum(u, 4)),
        "geomedian": jax.jit(agg.geomedian),
    }
    rows = []
    key = jax.random.PRNGKey(0)
    for m, d in sizes:
        u = jax.random.normal(key, (m, d), jnp.float32)
        for name, fn in rules.items():
            us = _timeit(fn, u)
            rows.append({"m": m, "d": d, "rule": name, "us_per_call": us})
            print(f"complexity m={m:3d} d={d:9,d} {name:14s} "
                  f"{us:12,.0f} us", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(full=ap.parse_args().full)
