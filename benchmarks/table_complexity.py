"""§4.4 time-complexity table: µs per aggregation call vs (m, d) for every
registered rule (XLA path, plus a ``<rule>_kernel`` Pallas variant for each
rule that declares one) — empirically confirms Trmean/Phocas ≈ O(dm) vs
Krum O(dm²).  CSV: results/table_complexity.csv."""
from __future__ import annotations

import argparse
import csv
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.core import registry


def _timeit(fn, u, reps=5) -> float:
    out = fn(u)
    jax.block_until_ready(out)                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(u)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main(out: str = "results/table_complexity.csv", full: bool = False):
    sizes = [(10, 100_000), (20, 100_000), (40, 100_000), (20, 1_000_000)]
    if full:
        sizes += [(80, 100_000), (20, 10_000_000)]
    params = registry.RuleParams(b=4, q=4)
    rules = {}
    for name in registry.available_rules():
        cls = registry.get_rule(name)
        label = name + ("_b4" if cls.uses_b else "_q4" if cls.uses_q else "")
        rules[label] = jax.jit(
            registry.make_rule(name, dataclasses.replace(
                params, backend="xla")).reduce)
        if cls.has_kernel:
            # Pallas path (not re-jitted: pallas_call manages its own tracing)
            rules[name + "_kernel"] = registry.make_rule(
                name, dataclasses.replace(params, backend="pallas")).reduce
    rows = []
    key = jax.random.PRNGKey(0)
    for i, (m, d) in enumerate(sizes):
        u = jax.random.normal(jax.random.fold_in(key, i), (m, d),
                              jnp.float32)
        for name, fn in rules.items():
            us = _timeit(fn, u)
            rows.append({"m": m, "d": d, "rule": name, "us_per_call": us})
            print(f"complexity m={m:3d} d={d:9,d} {name:14s} "
                  f"{us:12,.0f} us", flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(full=ap.parse_args().full)
