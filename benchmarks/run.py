"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines AND persists each
benchmark's rows as a machine-readable ``BENCH_<name>.json`` perf/quality
summary at the repo root (the artifact CI and trajectory tooling consume).

Scenario provenance: rows carry a ``"scenario"`` key with the
``repro.experiment.ScenarioSpec`` dict describing their cell.  For the
training benchmarks (fig2/fig3/fig4) the spec *produced* the row —
``ScenarioSpec.from_dict(row["scenario"]) `` + ``run_experiment`` re-runs
it exactly.  For detection (synthetic score loop) and survival
(closed-form probability) the spec is contextual: it names the rule ×
attack × q cell the row quantifies, not a training run behind the number.

  python -m benchmarks.run [--full] [--only fig2,detection,...]
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(name, fn, rows_to_csv):
    # Shared converter with the telemetry JSONL records: jax/numpy values
    # become plain types, NaN becomes null and ±inf clamps to ±1e308, so
    # the artifact stays strict JSON with all-numeric columns.
    from repro.defense.telemetry import jsonify
    t0 = time.time()
    rows = fn()
    us = (time.time() - t0) * 1e6
    for line in rows_to_csv(rows):
        print(line, flush=True)
    print(f"{name},{us:.0f},done", flush=True)
    out = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(out, "w") as f:
        json.dump(jsonify({"name": name, "wall_us": round(us),
                           "rows": rows}), f, indent=1, allow_nan=False)
    print(f"{name},0,wrote {os.path.basename(out)}", flush=True)
    return rows


def main(full: bool = False, only: str = "") -> None:
    sel = set(only.split(",")) if only else None
    pick = lambda n: sel is None or n in sel

    if pick("complexity"):
        from benchmarks.table_complexity import main as f
        _run("table_complexity", lambda: f(full=full),
             lambda rows: [
                 f"complexity/{r['rule']}/m{r['m']}/d{r['d']},"
                 f"{r['us_per_call']:.0f},us_per_call" for r in rows])

    if pick("bounds"):
        from benchmarks.bounds_check import main as f
        _run("bounds_check", lambda: f(trials=50 if not full else 200),
             lambda rows: [
                 f"bounds/{r['rule']}/q{r['q']}/b{r['b']},0,"
                 f"emp={r['empirical_mse']:.2f};delta={r['delta_bound']:.2f};"
                 f"holds={r['holds']}" for r in rows])

    if pick("fig2"):
        from benchmarks.fig2_attacks import main as f
        _run("fig2_attacks", lambda: f(full=full),
             lambda rows: [
                 f"fig2/{r['attack']}/{r['rule']},0,"
                 f"final_acc={r['final_acc']:.4f};max_acc={r['max_acc']:.4f}"
                 for r in rows])

    if pick("fig3"):
        from benchmarks.fig3_sensitivity import main as f
        _run("fig3_sensitivity", lambda: f(full=full),
             lambda rows: [
                 f"fig3/{r['panel']}/{r['rule']}/b{r['b_or_q']},0,"
                 f"final_acc={r['final_acc']:.4f}" for r in rows])

    if pick("fig4"):
        from benchmarks.fig4_batchsize import main as f
        _run("fig4_batchsize", lambda: f(full=full),
             lambda rows: [
                 f"fig4/bs{r['batch']}/{r['rule']},0,"
                 f"final_acc={r['final_acc']:.4f}" for r in rows])

    if pick("detection"):
        from benchmarks.fig_detection import main as f

        def _fmt(v):
            return "na" if v is None else f"{v:.2f}"

        _run("detection", lambda: f(full=full),
             lambda rows: [
                 f"detection/{r['attack']}/{r['rule']}/q{r['q']},0,"
                 f"prec={_fmt(r['precision'])};rec={_fmt(r['recall'])};"
                 f"qhat={r['q_hat']}" for r in rows])

    if pick("survival"):
        from benchmarks.survival import main as f
        _run("survival", lambda: f(),
             lambda rows: [
                 f"survival/ds{r['d_server']}/p{r['p']}/b{r['b_or_q']},0,"
                 f"dim={r['P_crash_dimensional']:.3e};"
                 f"classic={r['P_crash_classic']:.3e}" for r in rows])

    if pick("agg_scaling"):
        from benchmarks.fig_agg_scaling import main as f
        _run("agg_scaling", lambda: f(full=full),
             lambda rows: [
                 f"agg_scaling/{r['rule']}/{r['backend']}/m{r['m']}/d{r['d']}"
                 f"/b{r['b']},{r['us_plain']:.0f},"
                 f"fused={r['us_fused']:.0f}us;"
                 f"composed={r['us_composed']:.0f}us;"
                 f"f_vs_c={r['fused_vs_composed']:.2f}" for r in rows])

    if pick("overhead"):
        from benchmarks.overhead import main as f
        _run("overhead", lambda: f(),
             lambda rows: [
                 f"overhead/{r['rule']},{r['us_per_step']:.0f},"
                 f"x_mean={r['overhead_vs_mean']:.2f}" for r in rows])

    if pick("analysis"):
        from benchmarks.analysis_trend import main as f
        _run("analysis", lambda: f(),
             lambda rows: [
                 f"analysis/{r['rule']},0,count={r['count']}"
                 for r in rows if r["count"]] or ["analysis/clean,0,count=0"])

    if pick("obs"):
        from benchmarks.obs_smoke import main as f
        _run("obs", lambda: f(),
             lambda rows: [
                 f"obs/sync_ps,0,records={r['records']};"
                 f"series={r['series']};spans={r['span_observations']};"
                 f"qhat={r['q_hat']}" for r in rows])

    if pick("serve"):
        from benchmarks.bench_serve import main as f

        def _serve_line(r):
            if r.get("ms_per_step") is not None:
                return (f"serve/step/{r['mode']},"
                        f"{r['ms_per_step'] * 1e3:.0f},"
                        f"x_single={r['overhead_vs_single']:.2f}")
            return (f"serve/{r['mode']}/{r['rule']}/rate{r['arrival_rate']},"
                    f"0,p50={r['latency_p50_ms']:.0f}ms;"
                    f"p99={r['latency_p99_ms']:.0f}ms;"
                    f"tps={r['tokens_per_sec']:.1f}")

        _run("serve", lambda: f(full=full),
             lambda rows: [_serve_line(r) for r in rows])

    if pick("roofline"):
        from benchmarks.roofline import main as f
        _run("roofline", lambda: f(markdown=False),
             lambda rows: [
                 f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r['layout']},0,"
                 f"compute={r['compute_s']:.3f}s;memory={r['memory_s']:.3f}s;"
                 f"collective={r['collective_s']:.3f}s;dom={r['dominant']};"
                 f"useful={r['useful_ratio']:.2f}" for r in rows])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset, e.g. fig2,roofline")
    args = ap.parse_args()
    main(full=args.full, only=args.only)
