"""Perf-regression guard (CI).

Two budgets, each read from a fresh benchmark CSV:

* Aggregation (``results/overhead.csv``, written by ``benchmarks/overhead``):
  any guarded rule's ``overhead_vs_mean`` over budget fails.  Budgets are
  half the seed measurements (phocas 9.9x, mediam 10.2x): the
  shared-selection hot path (DESIGN.md §8) must keep dimensional
  robustness within ~a few x of plain averaging, per §4.4's O(dm)
  complexity claim.

* Serving (``results/serve_overhead.csv``, written by
  ``benchmarks/bench_serve``): the k=3 replicated phocas decode step must
  stay <= 3.5x a single-replica step — three vmapped replica forwards plus
  the logits aggregation; anything past ~3x forward cost means the
  aggregation stopped being negligible (DESIGN.md §11).

  python -m benchmarks.perf_guard [--csv results/overhead.csv]
                                  [--serve-csv results/serve_overhead.csv]

Each check runs iff its CSV path is non-empty, so CI stages guard only
what they just benchmarked.
"""
from __future__ import annotations

import argparse
import csv
import sys

# rule -> max allowed overhead_vs_mean (x a plain-mean train step, CPU CI)
BUDGETS = {
    "phocas": 5.0,   # seed: 9.9x
    "mediam": 5.1,   # seed: 10.2x
}

# decode mode -> max allowed overhead_vs_single (x a single-replica step)
SERVE_BUDGETS = {
    "phocas_k3": 3.5,
}


def check_aggregation(path: str) -> list:
    with open(path, newline="") as f:
        rows = {r["rule"]: float(r["overhead_vs_mean"])
                for r in csv.DictReader(f)}
    failures = []
    for rule, budget in BUDGETS.items():
        got = rows.get(rule)
        if got is None:
            failures.append(f"{rule}: missing from {path}")
        elif got > budget:
            failures.append(f"{rule}: overhead {got:.2f}x exceeds "
                            f"budget {budget:.1f}x")
        else:
            print(f"perf_guard {rule}: {got:.2f}x <= {budget:.1f}x OK")
    return failures


def check_serve(path: str) -> list:
    with open(path, newline="") as f:
        rows = {r["mode"]: float(r["overhead_vs_single"])
                for r in csv.DictReader(f)}
    failures = []
    for mode, budget in SERVE_BUDGETS.items():
        got = rows.get(mode)
        if got is None:
            failures.append(f"serve {mode}: missing from {path}")
        elif got > budget:
            failures.append(f"serve {mode}: decode step {got:.2f}x a "
                            f"single-replica step exceeds budget "
                            f"{budget:.1f}x")
        else:
            print(f"perf_guard serve {mode}: {got:.2f}x <= {budget:.1f}x OK")
    return failures


def main(path: str = "results/overhead.csv", serve_path: str = "") -> int:
    failures = []
    if path:
        failures += check_aggregation(path)
    if serve_path:
        failures += check_serve(serve_path)
    for msg in failures:
        print(f"perf_guard FAIL {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default="results/overhead.csv",
                    help="aggregation overhead CSV ('' skips the check)")
    ap.add_argument("--serve-csv", default="",
                    help="serving decode-step CSV ('' skips the check)")
    args = ap.parse_args()
    sys.exit(main(args.csv, args.serve_csv))
