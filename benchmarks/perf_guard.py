"""Aggregation perf-regression guard (CI).

Reads a fresh ``results/overhead.csv`` (written by ``benchmarks/overhead.py``)
and fails if any guarded rule's ``overhead_vs_mean`` exceeds its budget.
Budgets are half the seed measurements (phocas 9.9x, mediam 10.2x): the
shared-selection hot path (DESIGN.md §8) must keep dimensional robustness
within ~a few x of plain averaging, per §4.4's O(dm) complexity claim.

  python -m benchmarks.perf_guard [--csv results/overhead.csv]
"""
from __future__ import annotations

import argparse
import csv
import sys

# rule -> max allowed overhead_vs_mean (x a plain-mean train step, CPU CI)
BUDGETS = {
    "phocas": 5.0,   # seed: 9.9x
    "mediam": 5.1,   # seed: 10.2x
}


def main(path: str = "results/overhead.csv") -> int:
    with open(path, newline="") as f:
        rows = {r["rule"]: float(r["overhead_vs_mean"])
                for r in csv.DictReader(f)}
    failures = []
    for rule, budget in BUDGETS.items():
        got = rows.get(rule)
        if got is None:
            failures.append(f"{rule}: missing from {path}")
        elif got > budget:
            failures.append(f"{rule}: overhead {got:.2f}x exceeds "
                            f"budget {budget:.1f}x")
        else:
            print(f"perf_guard {rule}: {got:.2f}x <= {budget:.1f}x OK")
    for msg in failures:
        print(f"perf_guard FAIL {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default="results/overhead.csv")
    args = ap.parse_args()
    sys.exit(main(args.csv))
