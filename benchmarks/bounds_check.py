"""Theorems 1-2 empirical validation: Monte-Carlo E‖Aggr−g‖² vs the paper's
Δ₁/Δ₂ bounds under adversarial per-dimension corruption.
CSV: results/bounds_check.csv."""
from __future__ import annotations

import csv
import os

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg, bounds


def main(out: str = "results/bounds_check.csv", trials: int = 200):
    m, d = 20, 100
    V = float(d)
    rows = []
    key = jax.random.PRNGKey(0)
    for q in (1, 3, 6):
        for b in (q, q + 2, 8):
            if b > (m + 1) // 2 - 1:
                continue
            for rule, dfn in (("trmean", bounds.delta_trmean),
                              ("phocas", bounds.delta_phocas)):
                fn = jax.jit(agg.get_aggregator(rule, b=b))
                errs = []
                for t in range(trials):
                    k1, k2 = jax.random.split(jax.random.fold_in(key, t))
                    u = jax.random.normal(k1, (m, d))
                    ranks = jnp.argsort(jnp.argsort(
                        jax.random.uniform(k2, (m, d)), axis=0), axis=0)
                    tilde = jnp.where(ranks < q, 1e8, u)
                    errs.append(float(jnp.sum(fn(tilde) ** 2)))
                emp = sum(errs) / len(errs)
                theory = dfn(m, q, b, V)
                rows.append({"rule": rule, "m": m, "q": q, "b": b,
                             "empirical_mse": emp, "delta_bound": theory,
                             "holds": emp <= theory})
                print(f"bounds {rule:7s} q={q} b={b}: emp {emp:9.2f} "
                      f"<= Δ {theory:9.2f}  {'OK' if emp <= theory else 'VIOLATED'}",
                      flush=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=rows[0].keys())
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    main()
