"""Serving example: batched greedy generation against the KV-cache runtime,
with windowed ring-buffer caches (gemma-style local:global attention).

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import generate, make_serve_step

cfg = get_arch("gemma3-27b-reduced")         # 5:1 local:global pattern
model = build_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(key)

B, S0, NEW = 4, 8, 24
prompts = jax.random.randint(key, (B, S0), 0, cfg.vocab_size)

t0 = time.time()
out = generate(model, params, prompts, NEW)
dt = time.time() - t0
print(f"generated {out.shape} in {dt:.2f}s "
      f"({B * NEW / dt:.1f} tok/s, batched greedy)")
print("continuations:\n", out[:, S0:])

# the jitted single-token step used by a real serving loop:
step = make_serve_step(model, donate=False)
cache = model.init_cache(B, S0 + NEW)
tok, logits, cache = step(params, cache, prompts[:, :1], jax.numpy.int32(0))
print("serve_step OK:", tok.shape, logits.shape)
