"""Serving examples: the two tiers of the repro.serve runtime.

1. Dense tier — static-batch greedy ``generate`` (now with true batched
   prefill) against the ring-buffer KV cache; works for every arch in the
   zoo, including windowed gemma-style local:global patterns.
2. Paged tier — the continuous-batching ``ServeEngine`` (paged KV cache,
   per-request block tables, mid-loop join/retire) with k=3 replicated
   Byzantine-robust decode: one replica is corrupted with garbage
   parameters and the phocas-aggregated stream still matches the clean
   model's greedy output, while the replica's reputation collapses and it
   is ejected.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import (RobustDecoder, ServeEngine, corrupt_replica,
                         generate, make_replicas, make_serve_step)

# --- dense tier: batched greedy over the ring cache (windowed arch) -------
cfg = get_arch("gemma3-27b-reduced")         # 5:1 local:global pattern
model = build_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(key)

B, S0, NEW = 4, 8, 24
prompts = jax.random.randint(key, (B, S0), 0, cfg.vocab_size)

t0 = time.time()
out = generate(model, params, prompts, NEW)
dt = time.time() - t0
print(f"generated {out.shape} in {dt:.2f}s "
      f"({B * NEW / dt:.1f} tok/s, batched greedy)")
print("continuations:\n", out[:, S0:])

# the jitted single-token step used by a hand-rolled serving loop:
step = make_serve_step(model, donate=False)
cache = model.init_cache(B, S0 + NEW)
tok, logits, cache = step(params, cache, prompts[:, :1], jnp.int32(0))
print("serve_step OK:", tok.shape, logits.shape)

# --- paged tier: continuous batching + robust replicated decode -----------
cfg = get_arch("granite-8b-reduced")         # all-global GQA: paged-capable
model = build_model(cfg)
params = model.init(key)

replicas = corrupt_replica(make_replicas(params, 3), 2,
                           jax.random.PRNGKey(7))   # replica 2 -> garbage
engine = ServeEngine(model, replicas, max_slots=4, max_seq_len=64,
                     decoder=RobustDecoder(rule="phocas", k=3))

rng = np.random.default_rng(0)
reqs = [engine.submit(rng.integers(0, cfg.vocab_size, (6,)).tolist(), 16)
        for _ in range(6)]                    # 6 requests, 4 slots: queueing
t0 = time.time()
done = engine.run()
dt = time.time() - t0
toks = sum(len(r.generated) for r in done)
print(f"\nengine: {len(done)} requests / {toks} tokens in {dt:.2f}s "
      f"({toks / dt:.1f} tok/s, {engine.steps_run} steps, "
      f"continuous batching over 4 slots)")
print("ejected replicas (reputation defense):",
      engine.decoder.ejected_replicas())

clean = generate(model, params,
                 jnp.asarray([reqs[0].prompt], jnp.int32), 16)[0, 6:]
print("robust output == clean greedy despite 1 corrupted replica:",
      reqs[0].generated == [int(t) for t in np.asarray(clean)])
