"""Quickstart: the paper's contribution in 40 lines.

Robust aggregation of worker gradients under a dimensional Byzantine attack:
averaging breaks, the dimensional-resilient rules don't.  The rule list is
enumerated from the pluggable registry (`repro.core.registry`) — any rule
registered with ``@register_rule`` (see ``repro/core/rules/mediam.py`` for
the single-file plugin template) shows up here automatically.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AttackConfig, RobustConfig, aggregate_matrix, registry

key = jax.random.PRNGKey(0)
m, d = 20, 10_000                       # 20 workers, 10k-dim gradient

# Correct gradients: i.i.d. around the true gradient g = 1.0
g = jnp.ones((d,))
grads = g[None] + 0.1 * jax.random.normal(key, (m, d))

# Bit-flip attack (paper §5.1.3): 1 of the 20 values corrupted in each of
# the first 1000 dimensions — EVERY worker row is partially Byzantine, so
# classic (row-wise) defenses like Krum cannot help.
attack = AttackConfig(name="bitflip", num_byzantine=1, bitflip_dims=1000)

for rule in registry.available_rules():
    meta = registry.get_rule(rule)
    b = 2 if meta.uses_b else 0
    cfg = RobustConfig(rule=rule, b=b, q=2, attack=attack)
    agg = aggregate_matrix(grads, cfg, key=key)
    err = float(jnp.linalg.norm(agg - g) / jnp.linalg.norm(g))
    print(f"{rule:10s} [{meta.resilience:11s} resilience]  "
          f"relative aggregation error = {err:10.3e}")

print("\nMean and the classic (row-wise) rules are destroyed by per-dimension"
      "\ncorruption; the dimensional-resilient rules are unaffected.")
