"""Quickstart: the paper's contribution in 40 lines.

Robust aggregation of worker gradients under a dimensional Byzantine attack:
averaging breaks, Phocas doesn't.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import AttackConfig, RobustConfig, aggregate_matrix

key = jax.random.PRNGKey(0)
m, d = 20, 10_000                       # 20 workers, 10k-dim gradient

# Correct gradients: i.i.d. around the true gradient g = 1.0
g = jnp.ones((d,))
grads = g[None] + 0.1 * jax.random.normal(key, (m, d))

# Bit-flip attack (paper §5.1.3): 1 of the 20 values corrupted in each of
# the first 1000 dimensions — EVERY worker row is partially Byzantine, so
# classic (row-wise) defenses like Krum cannot help.
attack = AttackConfig(name="bitflip", num_byzantine=1, bitflip_dims=1000)

for rule, b in (("mean", 0), ("krum", 0), ("trmean", 2), ("phocas", 2)):
    cfg = RobustConfig(rule=rule, b=b, q=max(b, 1), attack=attack)
    agg = aggregate_matrix(grads, cfg, key=key)
    err = float(jnp.linalg.norm(agg - g) / jnp.linalg.norm(g))
    print(f"{rule:8s} (b={b}):  relative aggregation error = {err:10.3e}")

print("\nMean/Krum are destroyed by per-dimension corruption;"
      "\nTrmean/Phocas (dimensional Byzantine-resilient) are unaffected.")
