"""Quickstart: the paper's contribution as one declarative scenario grid.

Each cell of the experiment — model, data, aggregation rule, attack,
topology — is a frozen ``ScenarioSpec``; ``run_experiment`` is the single
entry point for every training path.  Here: every registered rule under
the paper's dimensional bit-flip attack (§5.1.3), where 1 of 20 values is
corrupted in each attacked dimension, so EVERY worker row is partially
Byzantine and classic (row-wise) defenses like Krum cannot help.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.core import AttackConfig, RobustConfig, registry
from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                              run_experiment)

base = ScenarioSpec(
    name="quickstart",
    topology="sync_ps",
    model=ModelSpec(kind="mlp"),
    data=DataSpec(kind="classification", dim=48, batch_per_worker=16),
    attack=AttackConfig(name="bitflip", num_byzantine=1, bitflip_dims=1000),
    num_workers=20, steps=30, log_every=10)

print(f"{'rule':10s} {'resilience':13s} final accuracy under bitflip")
for rule in registry.available_rules():
    meta = registry.get_rule(rule)
    b = 2 if meta.uses_b else 0
    spec = dataclasses.replace(
        base, name=f"quickstart-{rule}",
        robust=RobustConfig(rule=rule, b=b, q=2))
    result = run_experiment(spec)
    print(f"{rule:10s} [{meta.resilience:11s}]  acc = {result.final_eval:.3f}")

print("\nMean and the classic (row-wise) rules are destroyed by"
      "\nper-dimension corruption; the dimensional-resilient rules learn"
      "\nas if there were no failures.  Swap spec.topology for 'async_ps'"
      "\nor 'streaming' to run the same scenario on another training path.")
