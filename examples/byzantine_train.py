"""End-to-end driver: train a transformer from the model zoo with Byzantine
workers, comparing Mean vs a robust rule — two ScenarioSpecs that differ in
one field, both executed by the single ``run_experiment`` entry point.

  PYTHONPATH=src python examples/byzantine_train.py [--steps 300] \
      [--rule phocas] [--topology sync_ps|async_ps|streaming]
"""
import argparse
import dataclasses

from repro.core import AttackConfig, RobustConfig, registry
from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                              run_experiment)
from repro.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rule", default="phocas",
                    choices=registry.available_rules(),
                    help="robust rule to compare against plain Mean")
    # async_ps is omitted: its history records carry no loss (token models
    # have no eval either), so the loss comparison below would be empty —
    # see tests/test_experiment.py for the async path.
    ap.add_argument("--topology", default="sync_ps",
                    choices=("sync_ps", "streaming"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"))
    args = ap.parse_args()

    m = 8
    # The streaming scan cannot host colluding adversaries (it never sees
    # all workers at once); spec validation would reject omniscient there
    # with an actionable error, so pick a per-worker attack for it.
    attack = ("gaussian" if args.topology == "streaming" else "omniscient")
    base = ScenarioSpec(
        name=f"byz-{args.rule}",
        topology=args.topology,
        model=ModelSpec(kind="arch", arch="gemma2-2b-reduced"),
        data=DataSpec(kind="tokens", seq_len=128, batch_per_worker=2),
        robust=RobustConfig(rule=args.rule, b=2, q=2,
                            backend=args.backend),
        attack=AttackConfig(name=attack, num_byzantine=2),
        opt=OptConfig(name="sgd", lr=0.5),
        num_workers=m, steps=args.steps,
        log_every=max(args.steps // 10, 1))

    print(f"=== {args.rule} under {attack} attack "
          f"(2/{m} workers Byzantine, topology={args.topology}) ===")
    robust = run_experiment(base, verbose=True)

    print("\n=== Mean under the same attack ===")
    mean_spec = dataclasses.replace(
        base, name="byz-mean", robust=RobustConfig(rule="mean", b=0, q=2),
        steps=max(args.steps // 4, 20))
    mean = run_experiment(mean_spec, verbose=True)

    r0, r1 = robust.history[0], robust.history[-1]
    m0, m1 = mean.history[0], mean.history[-1]
    print(f"\n{args.rule}:  loss {r0['loss']:.3f} -> {r1['loss']:.3f}  "
          "(training works)")
    print(f"Mean:    loss {m0['loss']:.3f} -> {m1['loss']:.3f}  "
          "(diverges/stuck)")


if __name__ == "__main__":
    main()
