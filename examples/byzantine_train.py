"""End-to-end driver: train a ~100M-parameter transformer for a few hundred
steps with Byzantine workers, comparing Mean vs Phocas aggregation.

This is the full production path — model zoo config, data pipeline, robust
train step, optimizer, checkpointing — at a scale a laptop CPU can run.

  PYTHONPATH=src python examples/byzantine_train.py [--steps 300] [--small]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.core import AttackConfig, RobustConfig, registry
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def run(rule: str, attack: AttackConfig, cfg, steps: int, m: int = 8,
        backend: str = "auto"):
    model = build_model(cfg)
    # backend="auto" resolves per-rule through the registry: rules that
    # declare a Pallas kernel use it off-CPU, everything else stays on XLA.
    robust = RobustConfig(rule=rule, b=2, q=2, backend=backend, attack=attack)
    opt = OptConfig(name="sgd", lr=0.5)
    tcfg = TrainerConfig(num_workers=m, steps=steps,
                         log_every=max(steps // 10, 1))
    ds = TokenStream(vocab_size=cfg.vocab_size, seq_len=128,
                     global_batch=2 * m)
    trainer = Trainer(model, ds.batch, tcfg, robust, opt)
    hist = trainer.run(verbose=True)
    return hist[0]["loss"], hist[-1]["loss"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="2-layer reduced model (fast CI)")
    ap.add_argument("--rule", default="phocas",
                    choices=registry.available_rules(),
                    help="robust rule to compare against plain Mean")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"))
    args = ap.parse_args()

    base = get_arch("gemma2-2b-reduced")
    if args.small:
        cfg = base
    else:
        # ~100M params: widen the reduced config
        cfg = dataclasses.replace(
            base, name="gemma2-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, window_pattern=(256, None))
    n = sum(x.size for x in jax.tree.leaves(
        build_model(cfg).init(jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} ({n:,} params)\n")

    attack = AttackConfig(name="omniscient", num_byzantine=2)
    rule = args.rule
    print(f"=== {rule} under omniscient attack (2/8 workers Byzantine) ===")
    first_p, last_p = run(rule, attack, cfg, args.steps,
                          backend=args.backend)
    print("\n=== Mean under the same attack ===")
    first_m, last_m = run("mean", attack, cfg, max(args.steps // 4, 20))

    print(f"\n{rule}:  loss {first_p:.3f} -> {last_p:.3f}  (training works)")
    print(f"Mean:    loss {first_m:.3f} -> {last_m:.3f}  (diverges/stuck)")


if __name__ == "__main__":
    main()
