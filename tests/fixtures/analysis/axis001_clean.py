"""Clean twin of axis001_violation.py: vocabulary names and dynamic axis
expressions produce no findings."""
import jax


def vocab_axes(x):
    y = jax.lax.psum(x, "data")
    return jax.lax.all_gather(y, axis_name="model")


def multi_axis(x):
    return jax.lax.psum(x, ("pod", "data"))


def dynamic_axis(x, axes):
    return jax.lax.psum(x, axes)             # not statically checkable
