"""noqa escape-hatch fixture: each suppression style, plus one live
violation proving a mismatched rule id does NOT suppress."""
import jax


def targeted(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # repro: noqa[PRNG001] corpus demo
    return a + b


def bare(key):
    jax.random.split(key)  # repro: noqa
    return 0.0


def wrong_rule(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # repro: noqa[PRNG002] VIOLATION PRNG001
    return a + b
