"""Clean twin of prng004_violation.py: threaded seeds are the contract."""
import jax


def threaded(seed: int):
    return jax.random.normal(jax.random.PRNGKey(seed), (4,))
