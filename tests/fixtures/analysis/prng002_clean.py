"""Clean twin of prng002_violation.py."""
import jax


def all_consumed(key):
    ka, kb = jax.random.split(key)
    return jax.random.normal(ka, ()) + jax.random.uniform(kb, ())


def underscore_discard(key):
    ka, _ = jax.random.split(key)            # "_" is an explicit discard
    return jax.random.normal(ka, ())
