"""Seeded PALLAS002 violation: a layout cap redefined outside its owner
module (this fixture is obviously not kernels/trmean/kernel.py)."""

COUNTS_LANES = 64                            # VIOLATION PALLAS002 line 4
