"""Clean twin of contract_rule_violations.py: metadata that matches the
implementation produces zero findings under check_module."""
from repro.core.registry import AggregatorRule


class PlainClean(AggregatorRule):
    name = "fx_plain_clean"

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class ScoredClean(AggregatorRule):
    name = "fx_scored_clean"
    emits_scores = True
    uses_b = True
    fused_gate = True

    def _reduce_xla(self, u):
        b = self.params.b
        return u[b:].mean(axis=0)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        return mat.mean(axis=0), mat.sum(axis=1)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        return mat.mean(axis=0), mat.sum(axis=1)
