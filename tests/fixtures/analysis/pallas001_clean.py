"""Clean twin of pallas001_violation.py: multiples of 128, the scalar/
column idiom (lane == 1), and dynamic lanes all pass."""
from jax.experimental import pallas as pl

TILE = 256


def aligned_lane(m):
    return pl.BlockSpec((m, 128), lambda i: (0, i))


def aligned_constant(m):
    return pl.BlockSpec(block_shape=(m, TILE), index_map=lambda i: (0, i))


def scalar_column(m):
    return pl.BlockSpec((m, 1), lambda i: (0, i))


def dynamic_lane(m, tile_d):
    return pl.BlockSpec((m, tile_d), lambda i: (0, i))
