"""Seeded PRNG004 violations (only fire with library_code=True — the
engine treats src/repro paths as library code; this fixture is analyzed
with the flag forced by the test)."""
import jax


def baked_in_seed():
    return jax.random.normal(jax.random.PRNGKey(0), (4,))  # VIOLATION PRNG004


def argless():
    return jax.random.PRNGKey()              # VIOLATION PRNG004 line 12
