"""Clean twin of prng001_violation.py: the idiomatic split/fold patterns
must produce zero findings."""
import jax


def split_per_use(key):
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (4,))
    b = jax.random.uniform(kb, (4,))
    return a + b


def loop_advance(key, n):
    total = 0.0
    for _ in range(n):
        key, sub = jax.random.split(key)     # rebind advances the stream
        total += jax.random.normal(sub, ())
    return total


def loop_fold(key, n):
    total = 0.0
    for i in range(n):
        total += jax.random.normal(jax.random.fold_in(key, i), ())
    return total


def branch_arms(key, flag):
    # Sibling if/else arms are exclusive: one consumption each is fine.
    if flag:
        return jax.random.normal(key, ())
    return jax.random.uniform(key, ())
