"""Clean twin of contract_topology_violations.py."""
from repro.experiment.topology import Topology


class MatchedParams(Topology):
    name = "fx_matched_params"
    param_names = ("staleness", "update_clip")
    attack_allowlist = ("gaussian", "signflip")

    def run(self, plan, init_state=None):
        staleness = plan.spec.topology_params.get("staleness", 2)
        clip = plan.spec.topology_params["update_clip"]
        return staleness, clip
