"""Clean twin of pallas002_violation.py: importing the cap is the
single-sourcing contract (and unrelated constants are untouched)."""
from repro.kernels.trmean.kernel import COUNTS_LANES  # noqa: F401

MY_OWN_CAP = 64
