"""Seeded PALLAS001 violations: lane dims off the 128-lane tile."""
from jax.experimental import pallas as pl

TILE = 96


def bad_literal_lane(m):
    return pl.BlockSpec((m, 100), lambda i: (0, i))  # VIOLATION PALLAS001


def bad_constant_lane(m):
    return pl.BlockSpec(block_shape=(m, TILE),       # VIOLATION PALLAS001
                        index_map=lambda i: (0, i))
