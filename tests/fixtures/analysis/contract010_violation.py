"""Seeded CONTRACT010 violations: unregistered telemetry kinds at
``.log``/``.emit`` call sites."""


def typo_kind(tel, step, loss):
    tel.log("trian", step, loss=loss)        # VIOLATION CONTRACT010


def unregistered_kind(rec, step):
    rec.emit("heartbeat", step, ok=True)     # VIOLATION CONTRACT010


def forked_stream(writer, metrics):
    writer.log("serve_v2", 0,                # VIOLATION CONTRACT010
               **metrics)
