"""Seeded AXIS002 violations: shard_map spec arity mismatches."""
import jax
from jax.sharding import PartitionSpec as P


def agg_fn(mat, key):
    return mat.sum(axis=0), key


def wrong_in_specs(mesh, mat, key):
    f = jax.shard_map(                       # VIOLATION AXIS002 line 11
        agg_fn, mesh=mesh,
        in_specs=(P("data"),),               # agg_fn takes 2 args
        out_specs=(P(), P()))
    return f(mat, key)


def wrong_out_specs(mesh, mat, key):
    f = jax.shard_map(                       # VIOLATION AXIS002 line 19
        agg_fn, mesh=mesh,
        in_specs=(P("data"), P()),
        out_specs=(P(),))                    # agg_fn returns 2 values
    return f(mat, key)
