"""Clean twin of contract010_violation.py: registered kinds and
out-of-scope ``.log`` calls produce no findings."""
import math


def registered_kinds(tel, rec, step, loss):
    tel.log("train", step, loss=loss)
    rec.emit("serve", step, produced=3)
    rec.log("robust_decode", step, rule="phocas")


def not_the_bus(logger, x):
    # stdlib logging: first positional arg is a level int, not a kind.
    logger.log(10, "something happened %s", x)
    # math.log is a module function, not an attribute .log(...) with a
    # literal-str first arg + second positional.
    return math.log(x, 2)


def dynamic_kind(tel, kind, step):
    # Non-literal kinds are runtime-checked by Recorder.emit, not here.
    tel.log(kind, step, ok=True)


def single_arg(printer):
    # One positional argument: not the bus signature.
    printer.log("hello")
