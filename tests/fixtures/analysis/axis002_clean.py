"""Clean twin of axis002_violation.py."""
import jax
from jax.sharding import PartitionSpec as P


def agg_fn(mat, key):
    return mat.sum(axis=0), key


def correct_specs(mesh, mat, key):
    f = jax.shard_map(
        agg_fn, mesh=mesh,
        in_specs=(P("data"), P()),
        out_specs=(P(), P()))
    return f(mat, key)


def dynamic_wrapped(mesh, fn, mat):
    # Non-Name callee / dynamic specs are not statically checkable.
    return jax.shard_map(fn, mesh=mesh, in_specs=(P("data"),),
                         out_specs=P())(mat)
