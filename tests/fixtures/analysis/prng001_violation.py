"""Seeded PRNG001 violations: key reuse and loop reuse.

Line numbers are asserted exactly by tests/test_analysis.py — the marker
comments flag the lines under test, so edit with care.
"""
import jax


def double_consume(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))    # VIOLATION PRNG001 line 11
    return a + b


def loop_reuse(key, n):
    total = 0.0
    for _ in range(n):
        total += jax.random.normal(key, ())  # VIOLATION PRNG001 line 18
    return total
