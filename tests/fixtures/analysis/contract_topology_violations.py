"""Seeded topology contract violations (CONTRACT006, CONTRACT008)."""
from repro.experiment.topology import Topology


class ReadsUndeclaredParam(Topology):        # VIOLATION CONTRACT006
    name = "fx_reads_undeclared"
    param_names = ()

    def run(self, plan, init_state=None):
        staleness = plan.spec.topology_params.get("staleness", 2)
        return staleness


class DeclaresUnreadParam(Topology):         # VIOLATION CONTRACT006
    name = "fx_declares_unread"
    param_names = ("ghost_knob",)

    def run(self, plan, init_state=None):
        return None


class AllowsUnknownAttack(Topology):         # VIOLATION CONTRACT008
    name = "fx_allows_unknown_attack"
    attack_allowlist = ("gaussian", "fx_not_an_attack")

    def run(self, plan, init_state=None):
        return None
