"""Clean twin of contract_attack_violations.py."""
from repro.core.registry import AttackSpec


def _plain_factory(cfg):
    return lambda key, u: u


def _step_aware_factory(cfg):
    return lambda key, u, step=None: u


good_plain = AttackSpec(
    name="fx_plain", factory=_plain_factory, kind="classic")

good_step_aware = AttackSpec(
    name="fx_step_aware", factory=_step_aware_factory, kind="adaptive",
    step_aware=True)
