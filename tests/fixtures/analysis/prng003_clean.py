"""Clean twin of prng003_violation.py: stable derivations are fine."""
import zlib

import jax


def crc_seed(name):
    return jax.random.PRNGKey(zlib.crc32(name.encode()) & 0x7FFFFFFF)


def threaded_fold(key, step):
    return jax.random.fold_in(key, step)


def kwarg_seed(make_dataset, seed):
    return make_dataset(seed=seed)
