"""Seeded PRNG002 violations: split results that are never consumed."""
import jax


def discarded_split(key):
    jax.random.split(key)                    # VIOLATION PRNG002 line 6
    return 0.0


def dead_subkey(key):
    ka, kb = jax.random.split(key)           # VIOLATION PRNG002 line 11 (kb)
    return jax.random.normal(ka, ())
