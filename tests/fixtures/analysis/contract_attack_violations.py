"""Seeded attack-closure contract violations (CONTRACT005).

Plain AttackSpec instances (NOT register_attack'd): check_module audits
any AttackSpec value a scanned module defines.
"""
from repro.core.registry import AttackSpec


def _too_few_args_factory(cfg):              # VIOLATION CONTRACT005
    return lambda key: key                   # contract is (key, u[, step])


def _step_aware_without_step_factory(cfg):   # VIOLATION CONTRACT005
    return lambda key, u: u                  # declared step_aware below


def _required_extra_factory(cfg):            # VIOLATION CONTRACT005
    return lambda key, u, strength: u * strength  # no default, not step


bad_too_few = AttackSpec(
    name="fx_too_few", factory=_too_few_args_factory, kind="classic")

bad_stepless = AttackSpec(
    name="fx_stepless", factory=_step_aware_without_step_factory,
    kind="adaptive", step_aware=True)

bad_extra = AttackSpec(
    name="fx_extra", factory=_required_extra_factory, kind="classic")
