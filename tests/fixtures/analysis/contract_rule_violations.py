"""Seeded rule-metadata contract violations (CONTRACT001-004, 007).

One deliberately-broken AggregatorRule subclass per rule id, NOT
registered (no ``@register_rule``) so scanning never pollutes the
process-wide registry.  tests/test_analysis.py runs ``check_module`` over
this file and asserts each class line is flagged with its rule id.
"""
from repro.core.registry import AggregatorRule


class ScoresWithoutHook(AggregatorRule):     # VIOLATION CONTRACT001
    name = "fx_scores_without_hook"
    emits_scores = True                      # ...but no override below

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class HookWithoutScores(AggregatorRule):     # VIOLATION CONTRACT001
    name = "fx_hook_without_scores"
    emits_scores = False

    def _reduce_xla(self, u):
        return u.mean(axis=0)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        return mat.mean(axis=0), mat.sum(axis=1)


class KernelWithoutPallas(AggregatorRule):   # VIOLATION CONTRACT002
    name = "fx_kernel_without_pallas"
    has_kernel = True                        # ...but no _reduce_pallas

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class KernelBadDispatch(AggregatorRule):     # VIOLATION CONTRACT002
    name = "fx_kernel_bad_dispatch"
    has_kernel = True

    def _reduce_xla(self, u):
        return u.mean(axis=0)

    def _reduce_pallas(self, u):
        from repro.kernels.nonexistent.ops import reduce as k
        return k(u)


class StreamingUnimplemented(AggregatorRule):  # VIOLATION CONTRACT003
    name = "fx_streaming_unimplemented"
    supports_streaming = True                # not in STREAMING_IMPL_RULES

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class DeclaresUnreadB(AggregatorRule):       # VIOLATION CONTRACT004
    name = "fx_declares_unread_b"
    uses_b = True                            # never reads params.b

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class ReadsUndeclaredQ(AggregatorRule):      # VIOLATION CONTRACT004
    name = "fx_reads_undeclared_q"
    uses_q = False

    def _reduce_xla(self, u):
        return u[self.params.q:].mean(axis=0)


class FusedGateUnfused(AggregatorRule):      # VIOLATION CONTRACT007
    name = "fx_fused_gate_unfused"
    fused_gate = True                        # base two-pass composition

    def _reduce_xla(self, u):
        return u.mean(axis=0)


class FusedWithoutFlag(AggregatorRule):      # VIOLATION CONTRACT007
    name = "fx_fused_without_flag"
    fused_gate = False

    def _reduce_xla(self, u):
        return u.mean(axis=0)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        return mat.mean(axis=0), mat.sum(axis=1)
