"""Seeded AXIS001 violations: axis literals outside the vocabulary."""
import jax


def typo_axis(x):
    return jax.lax.psum(x, "dta")            # VIOLATION AXIS001 line 6


def unknown_role(x):
    return jax.lax.all_gather(x, axis_name="replica")  # VIOLATION AXIS001


def tuple_mix(x):
    return jax.lax.psum(x, ("data", "podd"))  # VIOLATION AXIS001 line 14
