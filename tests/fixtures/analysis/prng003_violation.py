"""Seeded PRNG003 violations: nondeterministic values feeding seeds."""
import random
import time

import jax


def hash_seed(name):
    return jax.random.PRNGKey(hash(name))    # VIOLATION PRNG003 line 9


def time_seed():
    return jax.random.PRNGKey(int(time.time()))  # VIOLATION PRNG003 line 13


def random_fold(key):
    return jax.random.fold_in(key, random.randint(0, 9))  # VIOLATION PRNG003


def kwarg_seed(make_dataset):
    return make_dataset(seed=int(time.time()))  # VIOLATION PRNG003 line 21
