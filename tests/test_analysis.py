"""Tests for repro.analysis — the repo-aware static-analysis pass.

The seeded-violation corpus in tests/fixtures/analysis/ carries an inline
``VIOLATION <RULE>`` marker comment ON every line a finding must anchor
to; expectations are derived from the markers so the assertions stay exact
(rule id + line) without hand-maintained line numbers.
"""
import json
import os
import re
import subprocess
import sys

import pytest

from repro.analysis import Finding, run_analysis
from repro.analysis import axes, layout, prng
from repro.analysis.contracts import (check_module, check_registry,
                                      _check_layout_invariants)
from repro.analysis.engine import analyze_file, collect_files
from repro.analysis.findings import apply_noqa, noqa_rules_of_line

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")

_MARK_RE = re.compile(r"VIOLATION (\w+)")


def _fx(name: str) -> str:
    return os.path.join(FIXTURES, name)


def _expected(path):
    """(rule, line) pairs from the fixture's VIOLATION markers."""
    out = set()
    with open(path) as fh:
        for i, line in enumerate(fh, 1):
            for m in _MARK_RE.finditer(line):
                out.add((m.group(1), i))
    return out


def _found(findings):
    return {(f.rule, f.line) for f in findings}


# ---------------------------------------------------------------------------
# AST-rule fixtures: every seeded violation caught at the exact line
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stem", ["prng001", "prng002", "prng003",
                                  "axis001", "axis002",
                                  "pallas001", "pallas002",
                                  "contract010"])
def test_ast_fixture_violations_exact(stem):
    path = _fx(f"{stem}_violation.py")
    with open(path) as fh:
        found = _found(analyze_file(path, fh.read()))
    assert found == _expected(path)


@pytest.mark.parametrize("stem", ["prng001", "prng002", "prng003",
                                  "prng004", "axis001", "axis002",
                                  "pallas001", "pallas002",
                                  "contract010"])
def test_ast_fixture_clean_twins(stem):
    path = _fx(f"{stem}_clean.py")
    with open(path) as fh:
        source = fh.read()
    assert analyze_file(path, source) == []
    # clean twins stay clean even under the stricter library-code PRNG set
    import ast
    assert prng.analyze(path, ast.parse(source), library_code=True) == []


def test_prng004_fires_only_in_library_code():
    import ast
    path = _fx("prng004_violation.py")
    with open(path) as fh:
        tree = ast.parse(fh.read())
    assert _found(prng.analyze(path, tree, library_code=True)) \
        == _expected(path)
    assert prng.analyze(path, tree, library_code=False) == []


# ---------------------------------------------------------------------------
# Contract fixtures (import + inspect via --scan-modules / check_module)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["contract_rule_violations.py",
                                  "contract_attack_violations.py",
                                  "contract_topology_violations.py"])
def test_contract_fixture_violations_exact(name):
    path = _fx(name)
    assert _found(check_module(path)) == _expected(path)


@pytest.mark.parametrize("name", ["contract_rule_clean.py",
                                  "contract_attack_clean.py",
                                  "contract_topology_clean.py"])
def test_contract_fixture_clean_twins(name):
    assert check_module(_fx(name)) == []


def test_breaking_a_registered_contract_is_detected(monkeypatch):
    """The CI acceptance property: flipping a registered rule's metadata
    without the matching hook fails the analysis job."""
    from repro.core import registry
    mean_cls = registry.get_rule("mean")
    monkeypatch.setattr(mean_cls, "emits_scores", True)
    found = check_registry()
    assert any(f.rule == "CONTRACT001" and "mean" in f.message
               for f in found)


def test_layout_invariants_live(monkeypatch):
    assert _check_layout_invariants() == []
    from repro.core import selection
    monkeypatch.setattr(selection, "_PAIRWISE_MAX_M",
                        selection._NETWORK_MAX_M + 1)
    assert any(f.rule == "PALLAS003"
               for f in _check_layout_invariants())


# ---------------------------------------------------------------------------
# noqa escape hatch
# ---------------------------------------------------------------------------

def test_noqa_parsing():
    assert noqa_rules_of_line("x = 1") is None
    assert noqa_rules_of_line("x = 1  # repro: noqa") == frozenset()
    assert noqa_rules_of_line("x  # repro: noqa[PRNG001] reason") \
        == frozenset({"PRNG001"})
    assert noqa_rules_of_line("x  # repro: noqa[PRNG001, AXIS002]") \
        == frozenset({"PRNG001", "AXIS002"})


def test_noqa_suppression_fixture():
    path = _fx("noqa_suppressed.py")
    found = _found(run_analysis([path], contracts=False))
    # only the wrong-rule-id noqa line survives
    assert found == _expected(path)


def test_noqa_pass_through_for_unreadable_paths():
    f = Finding(rule="CONTRACT001", path="<synthetic>", line=1,
                message="m", hint="h")
    assert apply_noqa([f], {}) == [f]


# ---------------------------------------------------------------------------
# Engine / CLI behavior
# ---------------------------------------------------------------------------

def test_fixture_corpus_skipped_on_directory_walks():
    files, saw_dir = collect_files([os.path.join(REPO, "tests")])
    assert saw_dir
    assert files and not any("fixtures" in f for f in files)
    # explicit file arguments bypass the skip
    explicit, _ = collect_files([_fx("prng001_violation.py")])
    assert explicit == [_fx("prng001_violation.py")]


def test_axis_vocabulary_matches_sharding_module():
    from repro.dist.sharding import AXIS_VOCAB
    assert axes.axis_vocabulary() == frozenset(AXIS_VOCAB)
    # the import-failure fallback must not drift from the real vocabulary
    assert axes._DEFAULT_VOCAB == frozenset(AXIS_VOCAB)


def test_layout_lane_matches_trmean_kernel():
    from repro.kernels.trmean.kernel import COUNTS_LANES
    assert layout.LANE == COUNTS_LANES == 128


def test_cli_jsonl_telemetry_compatible(tmp_path):
    from repro.analysis.__main__ import main
    from repro.defense.telemetry import read_jsonl
    out = tmp_path / "findings.jsonl"
    rc = main(["--scan-modules", _fx("contract_rule_violations.py"),
               "--jsonl", str(out)])
    assert rc == 1
    records = read_jsonl(str(out))
    assert records and all(r["kind"] == "analysis" for r in records)
    assert {"t", "kind", "step", "rule", "severity", "path", "line",
            "message", "hint"} <= set(records[0])
    assert any(r["rule"] == "CONTRACT001" for r in records)


def test_repo_is_clean_at_head():
    """Acceptance: python -m repro.analysis src/ benchmarks/ tests/
    exits 0 (every true positive fixed, every audited FP noqa'd)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "src", "benchmarks", "tests"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_unknown_rule_id_rejected():
    with pytest.raises(ValueError):
        Finding(rule="NOPE001", path="x.py", line=1, message="m")


def test_json_roundtrip_of_findings():
    f = Finding(rule="PRNG001", path="a.py", line=3, message="m", hint="h")
    rec = json.loads(json.dumps(f.to_record()))
    assert rec == {"rule": "PRNG001", "severity": "error", "path": "a.py",
                   "line": 3, "message": "m", "hint": "h"}
