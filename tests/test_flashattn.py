"""Flash-attention Pallas kernel: sweeps vs the naive oracle + model-level
equivalence (REPRO_FLASH_ATTN path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn.ops import flash_attention
from repro.kernels.flashattn.ref import flash_attention_ref

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, T, H, Kv, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd), dtype),
            jax.random.normal(ks[1], (B, T, Kv, hd), dtype),
            jax.random.normal(ks[2], (B, T, Kv, hd), dtype))


@pytest.mark.parametrize("S,H,Kv,hd", [(128, 4, 2, 64), (256, 8, 8, 32),
                                       (128, 6, 2, 128), (192, 2, 1, 64)])
def test_flash_matches_ref(S, H, Kv, hd):
    q, k, v = _qkv(2, S, S, H, Kv, hd)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


@pytest.mark.parametrize("window", [32, 64, 1024])
def test_flash_sliding_window(window):
    q, k, v = _qkv(1, 128, 128, 4, 4, 64)
    out = flash_attention(q, k, v, window=window, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_flash_softcap():
    q, k, v = _qkv(1, 128, 128, 4, 2, 64)
    out = flash_attention(q, k, v, cap=50.0, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, cap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_flash_bf16():
    q, k, v = _qkv(1, 128, 128, 4, 4, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_unaligned_seq_pads():
    q, k, v = _qkv(1, 96, 96, 2, 1, 64)
    out = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_model_level_flash_equivalence(monkeypatch):
    """attention_core with USE_FLASH_ATTN gives the same logits."""
    from repro.models import common as C
    from repro.configs import get_arch
    from repro.models import build_model
    cfg = get_arch("gemma2-2b-reduced")     # exercises softcap + windows
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    base, _ = model.forward(params, batch)
    monkeypatch.setattr(C, "USE_FLASH_ATTN", True)
    flash, _ = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(flash),
                               atol=5e-2, rtol=1e-2)
