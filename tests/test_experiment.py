"""repro.experiment: ScenarioSpec round-trips, registry-metadata
validation, the topology × rule × attack smoke grid, and shim-vs-new-path
trajectory equivalence for all three topologies."""
import dataclasses
import glob
import os

import numpy as np
import pytest

from repro.core import AttackConfig, RobustConfig
from repro.defense import DefenseConfig
from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec, SpecError,
                              available_topologies, run_experiment)
from repro.optim import OptConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M, DIM = 8, 16


def small_spec(**kw) -> ScenarioSpec:
    base = dict(
        name="t", topology="sync_ps",
        model=ModelSpec(kind="mlp"),
        data=DataSpec(kind="classification", dim=DIM, batch_per_worker=4),
        robust=RobustConfig(rule="phocas", b=2, q=2),
        attack=AttackConfig(name="gaussian", num_byzantine=2),
        num_workers=M, steps=3, log_every=1)
    base.update(kw)
    return ScenarioSpec(**base)


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

def test_to_json_from_json_identity():
    """Bit-identical round trip, nested configs and tuples included."""
    spec = small_spec(
        topology="async_ps",
        topology_params={"staleness": 3, "update_clip": 5.0},
        defense=DefenseConfig(reputation_decay=0.8, adapt_b=True),
        attack=AttackConfig(name="bitflip", num_byzantine=1,
                            bitflip_bits=(1, 2, 32)),
        schedule="cosine_decay", schedule_params={"final_frac": 0.2},
        opt=OptConfig(name="momentum", lr=0.05))
    s = spec.to_json()
    back = ScenarioSpec.from_json(s)
    assert back == spec
    assert back.to_json() == s                       # byte-identical
    # tuples come back as tuples (not lists) — dataclass equality is real
    assert back.attack.bitflip_bits == (1, 2, 32)
    assert isinstance(back.attack.bitflip_bits, tuple)
    assert back.defense.adapt_b is True
    assert back.topology_params == {"staleness": 3, "update_clip": 5.0}


def test_from_dict_rejects_unknown_fields():
    d = small_spec().to_dict()
    d["nope"] = 1
    with pytest.raises(SpecError, match="nope"):
        ScenarioSpec.from_dict(d)
    d2 = small_spec().to_dict()
    d2["robust"]["typo_field"] = 1
    with pytest.raises(SpecError, match="typo_field"):
        ScenarioSpec.from_dict(d2)


def test_checked_in_scenarios_are_canonical():
    """examples/scenarios/*.json: load, validate, and stay byte-identical
    under a round trip (the files are the spec's own canonical form)."""
    paths = sorted(glob.glob(os.path.join(REPO, "examples", "scenarios",
                                          "*.json")))
    assert len(paths) >= 6, paths      # CI smoke matrix: 3 topologies x 2
    topos = set()
    for p in paths:
        spec = ScenarioSpec.load(p).validate()
        topos.add(spec.topology)
        with open(p) as f:
            assert f.read() == spec.to_json() + "\n", p
    assert topos == set(available_topologies())


# ---------------------------------------------------------------------------
# Validation: actionable errors at spec-build time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mutation,match", [
    (dict(topology="ring"), "unknown topology"),
    (dict(robust=RobustConfig(rule="nope")), "unknown aggregation rule"),
    (dict(attack=AttackConfig(name="nope", num_byzantine=1)),
     "unknown attack"),
    (dict(robust=RobustConfig(rule="phocas", b=9)), "b <="),
    (dict(robust=RobustConfig(rule="krum", q=7)), "q <= m-3"),
    (dict(topology="streaming", robust=RobustConfig(rule="krum", q=2)),
     "streaming-capable"),
    (dict(topology="streaming",
          attack=AttackConfig(name="omniscient", num_byzantine=2)),
     "cannot be simulated"),
    (dict(topology="streaming", defense=DefenseConfig()),
     "does not support the defense"),
    (dict(robust=RobustConfig(rule="mean"), defense=DefenseConfig()),
     "score-emitting"),
    (dict(topology="async_ps", defense=DefenseConfig(adapt_b=True)),
     "adapt_b"),
    (dict(topology="async_ps", mesh="8x1"), "mesh"),
    (dict(topology="async_ps", topology_params={"tau": 3}),
     "unknown topology_params"),
    (dict(mesh="4x2"), "data axis"),
    (dict(mesh="abc"), "look like"),
    (dict(model=ModelSpec(kind="arch"), data=DataSpec(kind="tokens")),
     "model.arch"),
    (dict(model=ModelSpec(kind="arch", arch="gemma2-2b-reduced")),
     "tokens"),
    (dict(model=ModelSpec(kind="cnn")), "cnn_size"),
    (dict(model=ModelSpec(kind="mlp", dims=(4, 4, 10))), "data.dim"),
    (dict(schedule="linear"), "unknown schedule"),
    (dict(steps=0), "steps"),
    (dict(robust=RobustConfig(rule="phocas",
                              attack=AttackConfig(name="zero",
                                                  num_byzantine=1)),
          attack=AttackConfig(name="gaussian", num_byzantine=1)),
     "attack axis"),
    (dict(robust=RobustConfig(rule="median", backend="pallas")),
     "declares no"),
])
def test_invalid_specs_fail_with_actionable_errors(mutation, match):
    with pytest.raises(SpecError, match=match):
        small_spec(**mutation).validate()


def test_opt_lr_must_be_a_number():
    spec = small_spec(opt=OptConfig(lr=lambda s: 0.1))
    with pytest.raises(SpecError, match="schedule"):
        spec.validate()


def test_legacy_embedded_attack_is_honored():
    """A legacy RobustConfig with its own attack still works when the
    spec-level attack axis is clean."""
    spec = small_spec(
        robust=RobustConfig(rule="phocas", b=2, q=2,
                            attack=AttackConfig(name="zero",
                                                num_byzantine=1)),
        attack=AttackConfig(name="none"))
    assert spec.validate().effective_attack().name == "zero"
    assert spec.effective_robust().attack.name == "zero"


# ---------------------------------------------------------------------------
# Smoke grid: topology × rule × attack through the one entry point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", available_topologies())
@pytest.mark.parametrize("rule", ["mean", "phocas"])
@pytest.mark.parametrize("attack", ["none", "gaussian"])
def test_topology_rule_attack_smoke_grid(topology, rule, attack):
    if topology == "serve":
        # inference topology: decodes an arch-zoo model instead of training
        spec = small_spec(
            topology="serve",
            model=ModelSpec(kind="arch", arch="granite-8b-reduced"),
            data=DataSpec(kind="tokens"),
            robust=RobustConfig(rule=rule, b=1),
            attack=AttackConfig(name=attack, num_byzantine=1),
            topology_params={"replicas": 3, "max_slots": 2,
                             "max_seq_len": 16, "num_requests": 2,
                             "arrival_rate": 4.0, "prompt_len": 4,
                             "max_new_tokens": 4},
            steps=200)
        result = run_experiment(spec)
        assert result.spec is spec
        m = result.final_metrics
        assert m["completed"] == 2
        assert m["tokens"] == 2 * 4
        assert np.isfinite(m["tokens_per_sec"])
        return
    spec = small_spec(
        topology=topology,
        topology_params=({"staleness": 2} if topology == "async_ps" else {}),
        robust=RobustConfig(rule=rule, b=2, q=2),
        attack=AttackConfig(name=attack, num_byzantine=2))
    result = run_experiment(spec)
    assert result.spec is spec
    assert len(result.history) == spec.steps       # log_every=1
    last = result.history[-1]
    assert last["step"] == spec.steps - 1
    assert np.isfinite(last["eval"])
    if rule == "phocas" or attack == "none":
        # robust (or clean) runs keep finite losses end-to-end
        for rec in result.history:
            for v in rec.values():
                assert np.isfinite(v), (rec, result.history)


def test_schedule_resolution_changes_trajectory():
    s1 = small_spec(steps=4)
    s2 = small_spec(steps=4, schedule="cosine_decay",
                    schedule_params={"final_frac": 0.0})
    l1 = [r["loss"] for r in run_experiment(s1).history]
    l2 = [r["loss"] for r in run_experiment(s2).history]
    assert l1[0] == l2[0]                  # same init, same first step
    assert l1[-1] != l2[-1]                # decayed lr diverges the path


# ---------------------------------------------------------------------------
# Shim vs new path: identical trajectories on all three topologies
# ---------------------------------------------------------------------------

def _manual_parts(spec: ScenarioSpec):
    from repro.data import ClassificationData
    from repro.models.mlp import build_mlp_model, mlp_accuracy
    ds = spec.data
    data = ClassificationData(num_classes=ds.num_classes, dim=ds.dim,
                              noise=ds.noise, seed=ds.seed)
    model = build_mlp_model(dims=spec.model.dims)
    batch_fn = lambda i: data.batch(i, spec.num_workers  # noqa: E731
                                    * ds.batch_per_worker)
    test = data.test_set(1024)
    return model, batch_fn, lambda p: mlp_accuracy(p, test)


EQUIV = dict(model=ModelSpec(kind="mlp", dims=(DIM, 16, 10)),
             data=DataSpec(kind="classification", dim=DIM,
                           batch_per_worker=4, seed=3),
             robust=RobustConfig(rule="phocas", b=2, q=2),
             attack=AttackConfig(name="gaussian", num_byzantine=2),
             num_workers=M, steps=6, log_every=10)


def test_sync_shim_matches_run_experiment():
    from repro.train import Trainer, TrainerConfig
    spec = small_spec(**EQUIV)
    new = run_experiment(spec)
    model, batch_fn, eval_fn = _manual_parts(spec)
    tcfg = TrainerConfig(num_workers=M, steps=spec.steps, log_every=10,
                         seed=spec.seed)
    trainer = Trainer(model, batch_fn, tcfg, spec.effective_robust(),
                      spec.opt, eval_fn=eval_fn)
    old = trainer.run(verbose=False)
    assert [r["step"] for r in old] == [r["step"] for r in new.history]
    np.testing.assert_array_equal([r["loss"] for r in old],
                                  [r["loss"] for r in new.history])
    np.testing.assert_array_equal([r["eval"] for r in old],
                                  [r["eval"] for r in new.history])


def test_async_shim_matches_run_experiment():
    from repro.train.async_sgd import AsyncConfig, run_async_training
    spec = small_spec(**EQUIV)
    spec = dataclasses.replace(spec, topology="async_ps",
                               topology_params={"staleness": 2})
    new = run_experiment(spec)
    model, batch_fn, eval_fn = _manual_parts(spec)
    old = run_async_training(
        model, batch_fn, spec.effective_robust(), spec.opt,
        AsyncConfig(num_workers=M, staleness=2, seed=spec.seed),
        spec.steps, eval_fn=eval_fn)
    np.testing.assert_array_equal([r["eval"] for r in old],
                                  [r["eval"] for r in new.history])


def test_streaming_shim_matches_run_experiment():
    from repro.train.streaming import run_streaming_training
    spec = small_spec(**EQUIV)
    spec = dataclasses.replace(spec, topology="streaming")
    new = run_experiment(spec)
    model, batch_fn, eval_fn = _manual_parts(spec)
    old = run_streaming_training(
        model, batch_fn, spec.effective_robust(), spec.opt,
        num_workers=M, steps=spec.steps, seed=spec.seed, eval_fn=eval_fn)
    np.testing.assert_array_equal([r["loss"] for r in old],
                                  [r["loss"] for r in new.history])
    np.testing.assert_array_equal([r["eval"] for r in old],
                                  [r["eval"] for r in new.history])


# ---------------------------------------------------------------------------
# Result surface
# ---------------------------------------------------------------------------

def test_result_final_helpers_and_telemetry(tmp_path):
    tel = str(tmp_path / "tel.jsonl")
    spec = small_spec(topology="streaming", telemetry_path=tel,
                      attack=AttackConfig(name="none"))
    res = run_experiment(spec)
    assert res.final_loss == res.history[-1]["loss"]
    assert res.final_eval == res.history[-1]["eval"]
    assert res.eval_curve[-1][0] == spec.steps - 1
    from repro.defense import read_jsonl
    recs = read_jsonl(tel)
    assert len(recs) == spec.steps
    assert all(r["kind"] == "streaming" for r in recs)


# ---------------------------------------------------------------------------
# sweep + scenario-keyed result cache
# ---------------------------------------------------------------------------

def test_sweep_cartesian_product_and_names():
    from repro.experiment import sweep
    cells = sweep(small_spec(), {
        "robust.rule": ["phocas", "trmean"],
        "topology_params.staleness": [0, 4],
    }, validate=False)
    assert len(cells) == 4
    assert [c.robust.rule for c in cells] == ["phocas", "phocas",
                                              "trmean", "trmean"]
    assert cells[0].name == "t[rule=phocas,staleness=0]"
    assert cells[0].topology_params["staleness"] == 0
    assert cells[3].topology_params["staleness"] == 4
    # base spec untouched
    assert small_spec().topology_params.get("staleness") is None


def test_sweep_rejects_bad_path_and_invalid_cells():
    from repro.experiment import sweep
    with pytest.raises((AttributeError, TypeError, KeyError)):
        sweep(small_spec(), {"robust.nonsense": [1]}, validate=False)
    with pytest.raises(SpecError):  # validate-all-up-front
        sweep(small_spec(), {"robust.rule": ["phocas", "no-such-rule"]})


def test_scenario_key_tracks_content():
    from repro.experiment import scenario_key
    a, b = small_spec(), small_spec()
    assert scenario_key(a) == scenario_key(b)
    assert scenario_key(a) != scenario_key(small_spec(steps=4))


def test_run_cached_hit_and_mismatch(tmp_path):
    from repro.experiment import run_cached, scenario_key
    spec = small_spec(attack=AttackConfig(name="none"))
    cache = str(tmp_path / "cache")

    calls = []

    def runner(s, **kw):
        calls.append(s)
        return run_experiment(s, **kw)

    first = run_cached(spec, cache, runner=runner)
    again = run_cached(spec, cache, runner=runner)
    assert len(calls) == 1                       # second call was a hit
    assert again.params is None                  # cached results drop params
    assert again.final_metrics == pytest.approx(first.final_metrics)
    assert [h["loss"] for h in again.history] == \
           pytest.approx([h["loss"] for h in first.history])
    assert len(glob.glob(os.path.join(cache, "*.json"))) == 1

    # a different spec gets its own entry, not a collision
    other = small_spec(attack=AttackConfig(name="none"), steps=4)
    assert scenario_key(other) != scenario_key(spec)
    run_cached(other, cache, runner=runner)
    assert len(calls) == 2
    assert len(glob.glob(os.path.join(cache, "*.json"))) == 2
