"""Per-architecture smoke tests (reduced configs, CPU): forward shapes, no
NaNs, one train step, decode/forward consistency, SSD correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.core import RobustConfig
from repro.data import TokenStream, make_worker_batches
from repro.models import build_model
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def _batch(cfg, B=4, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.num_patches:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 1), (B, cfg.num_patches, cfg.vit_dim))
    if cfg.is_encdec:
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 2),
            (B, cfg.encoder_seq_len, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_arch(arch + "-reduced")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (4, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_train_step(arch):
    cfg = get_arch(arch + "-reduced")
    model = build_model(cfg)
    params = model.init(KEY)
    opt_cfg = OptConfig(name="sgd", lr=0.05)
    rob = RobustConfig(rule="trmean", b=1)
    step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                           num_workers=4, mesh=None, donate=False)
    opt_state = init_opt_state(opt_cfg, params)
    batch = make_worker_batches(_batch(cfg, B=8), 4)
    p2, o2, metrics = step(params, opt_state, batch, KEY)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert metrics["loss_per_worker"].shape == (4,)
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-2.7b",
                                  "deepseek-v2-lite-16b", "gemma3-27b",
                                  "hymba-1.5b"])
def test_decode_matches_forward(arch):
    cfg = get_arch(arch + "-reduced")
    if cfg.is_moe:   # raise capacity so no tokens drop (train-only semantics)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks, "labels": toks})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), atol=2e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("arch,pattern", [
    ("starcoder2-7b", (4,)),           # uniform window, ring wraps 2x
    ("gemma3-27b", (4, None)),         # mixed ring + absolute caches
    ("hymba-1.5b", (4,)),              # hybrid: ring + SSM state
])
def test_ring_buffer_wraparound(arch, pattern):
    """Decode with S >> window must match the parallel forward — exercises
    the ring-buffer modular position arithmetic past the wrap point
    (regression: the pre-fix code never entered the ring branch)."""
    cfg = dataclasses.replace(get_arch(arch + "-reduced"),
                              window_pattern=pattern)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12                       # S = 3x window
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks, "labels": toks})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-3, rtol=1e-3)


def test_sliding_window_limits_context():
    """Windowed attention must ignore tokens beyond the window."""
    cfg = dataclasses.replace(get_arch("starcoder2-7b-reduced"),
                              window_pattern=(4,))
    model = build_model(cfg)
    params = model.init(KEY)
    S = 12
    t1 = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)  # differ @ pos 0
    l1, _ = model.forward(params, {"tokens": t1, "labels": t1})
    l2, _ = model.forward(params, {"tokens": t2, "labels": t2})
    # with window 4 and 2 layers, receptive field < 8: last position immune
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_ssd_chunked_vs_recurrence():
    """Chunked SSD == step-by-step recurrence (the SSD duality)."""
    from repro.models.ssm import ssd_chunked
    b, S, h, p, n = 2, 32, 3, 4, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, S, h, p))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, S, h)))
    B = jax.random.normal(ks[2], (b, S, n))
    C = jax.random.normal(ks[3], (b, S, n))
    y_chunk, state_chunk = ssd_chunked(x, dA, B, C, chunk=8)

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(S):
        state = (jnp.exp(dA[:, t])[..., None, None] * state
                 + jnp.einsum("bhp,bn->bhpn", x[:, t], B[:, t]))
        ys.append(jnp.einsum("bhpn,bn->bhp", state, C[:, t]))
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                               atol=1e-4, rtol=1e-4)


def test_moe_router_load_balance_aux():
    from repro.models.moe import init_moe, moe_block
    cfg = get_arch("deepseek-v2-lite-16b-reduced")
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_block(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux) >= 0.0
    # aux loss minimal value is coef * 1.0 at perfect balance
    assert float(aux) >= cfg.router_aux_loss_coef * 0.99


def test_vlm_patch_positions_masked_in_loss():
    cfg = get_arch("internvl2-26b-reduced")
    model = build_model(cfg)
    params = model.init(KEY)
    b1 = _batch(cfg, B=2, S=16)
    # perturbing labels at patch positions must not change the loss
    b2 = dict(b1)
    b2["labels"] = b1["labels"].at[:, : cfg.num_patches].set(0)
    l1, l2 = model.loss(params, b1), model.loss(params, b2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_gemma2_softcaps_bound_logits():
    cfg = get_arch("gemma2-2b-reduced")
    model = build_model(cfg)
    params = model.init(KEY)
    logits, _ = model.forward(params, _batch(cfg))
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3
