"""repro.defense: suspicion scores (every emits_scores rule × attacks ×
both collective layouts), reputation dynamics + checkpoint round-trip,
online q̂ detection, telemetry, and the ISSUE acceptance case (m=40, q=8
signflip: phocas ranks all Byzantine workers in the top q within 5 steps).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttackConfig, RobustConfig, aggregate_matrix,
                        gate_matrix, registry)
from repro.defense import (DefenseConfig, TelemetryWriter, estimate_q,
                           init_reputation, read_jsonl, resilience_monitor,
                           suspicion_of, update_reputation)

KEY = jax.random.PRNGKey(7)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M, D, Q = 20, 64, 4


def _attacked_scores(rule, attack, q=Q, m=M, d=D, b=Q, seed=0):
    key = jax.random.fold_in(KEY, seed)
    u = 1.0 + 0.1 * jax.random.normal(key, (m, d))
    cfg = RobustConfig(rule=rule, b=b, q=b,
                       attack=AttackConfig(name=attack, num_byzantine=q))
    _, scores = aggregate_matrix(u, cfg, key=key, with_scores=True)
    return np.asarray(scores)


# ---------------------------------------------------------------------------
# Score contract + registry metadata
# ---------------------------------------------------------------------------

def test_emits_scores_metadata():
    emitting = set(registry.score_rules())
    for name in ("trmean", "phocas", "krum", "multikrum", "geomedian",
                 "mediam"):
        assert name in emitting, name
    # mean's uniform default is intentionally NOT flagged as informative
    assert "mean" not in emitting
    assert not registry.get_rule("mean").emits_scores


def test_uniform_default_for_non_emitting_rules():
    u = jax.random.normal(KEY, (8, 16))
    agg, scores = registry.make_rule("mean").reduce_with_scores(u)
    np.testing.assert_allclose(np.asarray(scores), np.zeros(8))
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(jnp.mean(u, axis=0)), atol=1e-6)


@pytest.mark.parametrize("attack", ["signflip", "gaussian", "innerprod"])
@pytest.mark.parametrize("rule", registry.score_rules())
def test_suspicion_concentrates_on_byzantine(rule, attack):
    """Every emits_scores rule puts the q Byzantine rows on top, for the
    row-wise attacks including the adaptive inner-product manipulation."""
    scores = _attacked_scores(rule, attack)
    assert scores.shape == (M,)
    assert np.all(scores >= 0) and np.all(scores <= 1)
    top = set(np.argsort(-scores)[:Q].tolist())
    assert top == set(range(Q)), (rule, attack, scores)
    # decisive margin between the Byzantine and benign populations
    assert scores[:Q].min() > scores[Q:].max() + 0.2, (rule, attack)


@pytest.mark.parametrize("rule", registry.score_rules())
def test_clean_run_scores_stay_low(rule):
    scores = _attacked_scores(rule, "none", q=0)
    assert scores.max() < 0.5, (rule, scores)
    assert int(estimate_q(jnp.asarray(scores))) == 0


def test_agg_matches_plain_reduce():
    """reduce_with_scores must not change the aggregation result."""
    u = 2.0 + jax.random.normal(KEY, (M, D))
    for rule in registry.score_rules():
        cfg = RobustConfig(rule=rule, b=2, q=2)
        ref = np.asarray(aggregate_matrix(u, cfg))
        got, _ = aggregate_matrix(u, cfg, with_scores=True)
        np.testing.assert_allclose(np.asarray(got), ref, atol=1e-5,
                                   err_msg=rule)


# ---------------------------------------------------------------------------
# ISSUE acceptance: m=40, q=8 signflip, phocas, 5 steps
# ---------------------------------------------------------------------------

def test_phocas_ranks_all_byzantine_within_5_steps_m40():
    m, q, d = 40, 8, 256
    cfg = RobustConfig(rule="phocas", b=q, q=q,
                       attack=AttackConfig(name="signflip", num_byzantine=q))
    dcfg = DefenseConfig()
    state = init_reputation(m)
    for t in range(5):
        key = jax.random.fold_in(KEY, t)
        u = 1.0 + 0.1 * jax.random.normal(key, (m, d))
        _, scores = aggregate_matrix(u, cfg, key=key, with_scores=True)
        state = update_reputation(state, scores, dcfg)
    susp = np.asarray(suspicion_of(state))
    top = set(np.argsort(-susp)[:q].tolist())
    assert top == set(range(q)), susp


# ---------------------------------------------------------------------------
# Reputation dynamics
# ---------------------------------------------------------------------------

def test_reputation_eject_and_readmit_hysteresis():
    m = 6
    cfg = DefenseConfig(reputation_decay=0.5, eject_below=0.5,
                        readmit_above=0.7, warmup_steps=1)
    state = init_reputation(m)
    bad = jnp.zeros((m,)).at[0].set(1.0)           # worker 0 suspicious
    for _ in range(6):
        state = update_reputation(state, bad, cfg)
    assert float(state["active"][0]) == 0.0        # ejected
    assert np.all(np.asarray(state["active"][1:]) == 1.0)
    # transiently-faulty worker recovers: feed clean scores until readmission
    clean = jnp.zeros((m,))
    for _ in range(6):
        state = update_reputation(state, clean, cfg)
    assert float(state["active"][0]) == 1.0        # readmitted
    # warmup: no ejection on the very first updates
    s2 = update_reputation(init_reputation(m), bad,
                           DefenseConfig(reputation_decay=0.01,
                                         warmup_steps=3))
    assert float(s2["active"][0]) == 1.0


def test_reputation_gate_replaces_ejected_rows():
    u = jax.random.normal(KEY, (8, 5))
    active = jnp.ones((8,)).at[2].set(0.0)
    gated = gate_matrix(u, active)
    med = jnp.median(u, axis=0)
    np.testing.assert_allclose(np.asarray(gated[2]), np.asarray(med))
    np.testing.assert_allclose(np.asarray(gated[0]), np.asarray(u[0]))


def test_reputation_state_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.io import load_checkpoint, save_checkpoint
    cfg = DefenseConfig()
    state = init_reputation(12)
    for t in range(4):
        scores = jnp.clip(jax.random.uniform(jax.random.fold_in(KEY, t),
                                             (12,)), 0, 1)
        state = update_reputation(state, scores, cfg)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, {"defense": state}, step=4)
    restored, step = load_checkpoint(path, {"defense": init_reputation(12)})
    assert step == 4
    for k in state:
        np.testing.assert_array_equal(np.asarray(restored["defense"][k]),
                                      np.asarray(state[k]), err_msg=k)


def test_defense_config_validation():
    with pytest.raises(ValueError, match="reputation_decay"):
        DefenseConfig(reputation_decay=1.5)
    with pytest.raises(ValueError, match="hysteresis"):
        DefenseConfig(eject_below=0.8, readmit_above=0.5)


# ---------------------------------------------------------------------------
# Detector
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q", [0, 2, 4, 8])
def test_detector_qhat_within_one_synthetic(q):
    """q̂ within ±1 of the true q across the synthetic suite (every
    emits_scores rule under signflip/gaussian)."""
    for rule in registry.score_rules():
        for attack in ("signflip", "gaussian"):
            if q == 0:
                scores = _attacked_scores(rule, "none", q=0)
            else:
                scores = _attacked_scores(rule, attack, q=q, b=max(q, 2))
            q_hat = int(estimate_q(jnp.asarray(scores)))
            assert abs(q_hat - q) <= 1, (rule, attack, q, q_hat, scores)


def test_detector_caps_at_half():
    # a majority-suspicious vector is uninformative, not a count
    scores = jnp.concatenate([jnp.ones((15,)), jnp.zeros((5,))])
    assert int(estimate_q(scores)) <= 10


def test_resilience_monitor_clean_within_bound():
    u = 1.0 + 0.1 * jax.random.normal(KEY, (M, D))
    cfg = RobustConfig(rule="phocas", b=4, q=4)
    agg, scores = aggregate_matrix(u, cfg, with_scores=True)
    rep = resilience_monitor(u, agg, scores, rule_name="phocas", b=4)
    assert rep["q_hat"] == 0
    assert rep["delta_bound"] is not None and rep["within_bound"]


def test_resilience_monitor_flags_broken_rule():
    """Mean under signflip: the aggregate leaves the benign envelope."""
    key = jax.random.fold_in(KEY, 1)
    u = 1.0 + 0.1 * jax.random.normal(key, (M, D))
    cfg = RobustConfig(rule="mean", b=4, q=4,
                       attack=AttackConfig(name="signflip", num_byzantine=4))
    agg = aggregate_matrix(u, cfg, key=key)
    # score with phocas (mean itself is score-blind), bound for phocas
    cfg2 = RobustConfig(rule="phocas", b=4, q=4,
                        attack=AttackConfig(name="signflip",
                                            num_byzantine=4))
    _, scores = aggregate_matrix(u, cfg2, key=key, with_scores=True)
    rep = resilience_monitor(u, np.asarray(agg), scores,
                             rule_name="phocas", b=4)
    assert rep["q_hat"] == 4
    assert rep["within_bound"] is False


# ---------------------------------------------------------------------------
# Train-step integration (local) + streaming keying fix + telemetry
# ---------------------------------------------------------------------------

def test_defense_train_step_ejects_byzantine_workers():
    from repro.data import ClassificationData, make_worker_batches
    from repro.models.mlp import build_mlp_model
    from repro.optim import OptConfig, init_opt_state
    from repro.train import make_train_step
    m, q = 8, 2
    data = ClassificationData(num_classes=10, dim=32, noise=0.8, seed=1)
    model = build_mlp_model(dims=(32, 32, 10))
    params = model.init(KEY)
    opt_cfg = OptConfig(name="sgd", lr=0.1)
    rob = RobustConfig(rule="phocas", b=q, q=q,
                       attack=AttackConfig(name="signflip",
                                           num_byzantine=q))
    dcfg = DefenseConfig(reputation_decay=0.6, warmup_steps=1)
    step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                           num_workers=m, mesh=None, donate=False,
                           defense_cfg=dcfg)
    opt_state = init_opt_state(opt_cfg, params)
    defense = init_reputation(m)
    for i in range(8):
        batch = make_worker_batches(data.batch(i, 16 * m), m)
        params, opt_state, defense, mt = step(
            params, opt_state, batch, jax.random.fold_in(KEY, i), defense)
    active = np.asarray(defense["active"])
    assert np.all(active[:q] == 0.0), active       # Byzantine ejected
    assert np.all(active[q:] == 1.0), active       # honest workers kept
    assert int(mt["q_hat"]) == q
    assert np.isfinite(float(mt["loss"]))


def test_streaming_gaussian_keying_is_path_derived():
    """Same-shape leaves must draw DIFFERENT noise (the old
    hash(str(shape)) salt collided), and the salt must not depend on
    process-specific state."""
    from repro.train.streaming import _path_salt, _worker_attack
    g = {"a": jnp.zeros((4, 3)), "b": jnp.zeros((4, 3))}
    cfg = AttackConfig(name="gaussian", num_byzantine=1, gaussian_std=1.0)
    out = _worker_attack(cfg, g, widx=jnp.int32(0), key=KEY)
    assert not np.allclose(np.asarray(out["a"]), np.asarray(out["b"]))
    # salt is a pure function of the tree path
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(g)[0]]
    salts = [_path_salt(p) for p in paths]
    assert len(set(salts)) == len(salts)
    assert salts == [_path_salt(p) for p in paths]


def test_telemetry_writer_roundtrip(tmp_path):
    path = str(tmp_path / "tel.jsonl")
    with TelemetryWriter(path) as tel:
        tel.log("train", 0, loss=0.5, suspicion=jnp.array([0.0, 1.0]),
                q_hat=jnp.int32(1), note="ok")
        tel.log("serve", 3, tok_s=123.4)
        # non-finite floats must survive as strict JSON: NaN -> null,
        # +/-inf -> the +/-1e308 clamp (a diverged loss would otherwise
        # produce a line json.loads rejects in strict mode)
        tel.log("train", 4, loss=float("nan"), grad_norm=float("inf"),
                suspicion=jnp.array([0.5, jnp.inf]))
    recs = read_jsonl(path)
    assert len(recs) == 3
    assert recs[0]["kind"] == "train" and recs[0]["suspicion"] == [0.0, 1.0]
    assert recs[0]["q_hat"] == 1 and recs[1]["step"] == 3
    assert recs[2]["loss"] is None
    assert recs[2]["grad_norm"] == 1e308
    assert recs[2]["suspicion"] == [0.5, 1e308]
    with open(path) as fh:            # every line is strict JSON
        for line in fh:
            json.loads(line, parse_constant=lambda c: 1 / 0)
    # disabled writer is a no-op
    off = TelemetryWriter(None)
    off.log("train", 0, loss=1.0)
    off.close()


def test_trainer_defense_telemetry_and_checkpoint(tmp_path):
    from repro.data import ClassificationData
    from repro.models.mlp import build_mlp_model
    from repro.optim import OptConfig
    from repro.train import Trainer, TrainerConfig
    data = ClassificationData(num_classes=10, dim=16, noise=0.8, seed=0)
    model = build_mlp_model(dims=(16, 16, 10))
    tel = str(tmp_path / "tel.jsonl")
    ckpt = str(tmp_path / "ck")
    tcfg = TrainerConfig(num_workers=8, steps=5, log_every=2,
                         checkpoint_path=ckpt, checkpoint_every=4)
    rob = RobustConfig(rule="phocas", b=2, q=2,
                       attack=AttackConfig(name="gaussian", num_byzantine=2))
    trainer = Trainer(model, lambda i: data.batch(i, 16 * 8), tcfg, rob,
                      OptConfig(name="sgd", lr=0.1),
                      defense_cfg=DefenseConfig(telemetry_path=tel))
    hist = trainer.run(verbose=False)
    assert hist and "q_hat" in hist[-1]
    recs = read_jsonl(tel)
    assert len(recs) == 5 and all(r["kind"] == "train" for r in recs)
    assert len(recs[0]["reputation"]) == 8
    # reputation state round-trips through the Trainer checkpoint
    saved = np.asarray(trainer.defense_state["reputation"])
    trainer.defense_state = init_reputation(8)
    step = trainer.restore(ckpt)
    assert step == 4
    # restored state is the one saved at step 4 (not the final one)
    assert trainer.defense_state["reputation"].shape == (8,)
    assert float(trainer.defense_state["steps"]) == 5  # 0-indexed step 4
    del saved


def test_async_defense_threads_reputation():
    from repro.data import ClassificationData
    from repro.models.mlp import build_mlp_model
    from repro.optim import OptConfig
    from repro.train.async_sgd import AsyncConfig, run_async_training
    data = ClassificationData(num_classes=10, dim=16, noise=0.8, seed=0)
    model = build_mlp_model(dims=(16, 16, 10))
    rob = RobustConfig(rule="trmean", b=2, q=2,
                       attack=AttackConfig(name="signflip", num_byzantine=2))
    hist = run_async_training(
        model, lambda i: data.batch(i, 8 * 8), rob,
        OptConfig(name="sgd", lr=0.05),
        AsyncConfig(num_workers=8, staleness=2), steps=12,
        eval_fn=lambda p: jnp.float32(0.0),
        defense_cfg=DefenseConfig())
    assert hist and hist[-1]["q_hat"] == 2


# ---------------------------------------------------------------------------
# Distributed round-trip: scores through both collective layouts
# ---------------------------------------------------------------------------

DIST_SCORES = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import (RobustConfig, AttackConfig, robust_aggregate_dist,
                        aggregate_matrix, registry)
from jax.flatten_util import ravel_pytree

mesh = jax.make_mesh((4, 2), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
key = jax.random.PRNGKey(1)
base = 1.0 + 0.1*jax.random.normal(key, (4, 64))
base = base.at[0].set(-10.0 * base[0])       # worker 0 Byzantine (signflip)
grads = {'w': base[:, :60], 'b': base[:, 60:]}
mat = np.stack([ravel_pytree(jax.tree.map(lambda x: x[i], grads))[0]
                for i in range(4)])
results = {}
for rule in registry.score_rules():
    cfg_l = RobustConfig(rule=rule, b=1, q=1)
    ref_agg, ref_scores = aggregate_matrix(jnp.asarray(mat), cfg_l,
                                           with_scores=True)
    for layout in ['replicated', 'sharded']:
        cfg = RobustConfig(rule=rule, b=1, q=1, layout=layout)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P('data'),),
                 out_specs=(P(), P()), check_vma=False)
        def f(g):
            local = jax.tree.map(lambda x: x[0], g)
            tree, scores = robust_aggregate_dist(
                local, cfg, worker_axes=('data',), model_axes=('model',),
                with_scores=True)
            return ravel_pytree(tree)[0], scores
        flat, scores = f(grads)
        ok_agg = bool(np.allclose(np.asarray(flat), np.asarray(ref_agg),
                                  atol=1e-4))
        ok_sc = bool(np.allclose(np.asarray(scores), np.asarray(ref_scores),
                                 atol=1e-4))
        ok_top = bool(int(np.argmax(np.asarray(scores))) == 0)
        results[f'{rule}/{layout}'] = ok_agg and ok_sc and ok_top

# reputation-gated aggregation through shard_map: ejecting the Byzantine
# worker recovers (approximately) the clean-benign aggregate
cfg = RobustConfig(rule='trmean', b=1, q=1, layout='sharded')
active = jnp.ones((4,)).at[0].set(0.0)
@partial(jax.shard_map, mesh=mesh, in_specs=(P('data'), P()),
         out_specs=P(), check_vma=False)
def g(g_, act):
    local = jax.tree.map(lambda x: x[0], g_)
    return ravel_pytree(robust_aggregate_dist(
        local, cfg, worker_axes=('data',), model_axes=('model',),
        active=act))[0]
gated = np.asarray(g(grads, active))
results['gate/sharded'] = bool(np.isfinite(gated).all()
                               and np.abs(gated - 1.0).max() < 1.0)
print(json.dumps(results))
"""


@pytest.mark.slow
def test_scores_distributed_roundtrip():
    """Every emits_scores rule reproduces its single-host scores through
    both collective layouts (the psum contract), and the reputation gate
    composes with shard_map."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", DIST_SCORES],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(results) == 2 * len(registry.score_rules()) + 1
    bad = [k for k, v in results.items() if not v]
    assert not bad, bad


# ---------------------------------------------------------------------------
# innerprod attack registration (satellite)
# ---------------------------------------------------------------------------

def test_innerprod_attack_registered_and_norm_stealthy():
    spec = registry.get_attack_spec("innerprod")
    assert spec.kind == "classic" and spec.paper_q == 6
    key = jax.random.PRNGKey(0)
    u = 1.0 + 0.1 * jax.random.normal(key, (M, D))
    cfg = AttackConfig(name="innerprod", num_byzantine=6)
    from repro.core.attacks import make_attack
    ut = make_attack(cfg)(key, u)
    byz_norm = float(jnp.linalg.norm(ut[0]))
    benign_norm = float(jnp.mean(jnp.linalg.norm(ut[6:], axis=1)))
    # benign-looking magnitude (the stealth property)...
    assert byz_norm < 5 * benign_norm
    # ...but the direction is flipped: negative inner product with the mean
    correct = jnp.mean(u[6:], axis=0)
    assert float(jnp.dot(ut[0], correct)) < 0
    # rows are mutually identical (the collusion that traps Krum selection)
    np.testing.assert_allclose(np.asarray(ut[0]), np.asarray(ut[5]))


def test_innerprod_rejected_in_streaming_mode():
    from repro.train.streaming import _worker_attack
    with pytest.raises(ValueError, match="innerprod"):
        _worker_attack(AttackConfig(name="innerprod", num_byzantine=2),
                       {"w": jnp.ones((3,))}, jnp.int32(0), KEY)


# ---------------------------------------------------------------------------
# slowburn: the reputation-EMA-targeting adaptive attack (satellite)
# ---------------------------------------------------------------------------

def test_slowburn_registered_as_step_aware_adaptive():
    spec = registry.get_attack_spec("slowburn")
    assert spec.kind == "adaptive" and spec.step_aware


def test_slowburn_mimics_then_strikes():
    """Phase semantics at the matrix level: pre-trigger rows sit at the
    benign mean (maximally conforming), post-trigger rows are a coordinated
    inner-product strike; no step = worst case (strike)."""
    from repro.core.attacks import make_attack
    key = jax.random.fold_in(KEY, 3)
    u = 1.0 + 0.1 * jax.random.normal(key, (M, D))
    atk = make_attack(AttackConfig(name="slowburn", num_byzantine=6,
                                   slowburn_trigger=10))
    mean = np.broadcast_to(np.asarray(jnp.mean(u[6:], axis=0)), (6, D))
    mimic = np.asarray(atk(key, u, jnp.int32(0)))
    np.testing.assert_allclose(mimic[:6], mean, atol=0.05)
    strike = np.asarray(atk(key, u, jnp.int32(10)))
    np.testing.assert_allclose(strike[:6], -100.0 * mean, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(atk(key, u)), strike)
    # benign rows untouched in both phases
    np.testing.assert_allclose(mimic[6:], np.asarray(u)[6:])


def test_slowburn_defeats_then_loses_to_reputation_via_scenario():
    """Through a ScenarioSpec: during the trust-building phase the detector
    sees nothing (q̂=0, everyone active — the attack's design); after the
    strike the scores spike, the banked reputation drains over the EMA lag,
    and the colluders end ejected."""
    import dataclasses
    from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                                  run_experiment)
    spec = ScenarioSpec(
        name="slowburn", topology="sync_ps",
        model=ModelSpec(kind="mlp", dims=(32, 32, 10)),
        data=DataSpec(kind="classification", dim=32, batch_per_worker=8,
                      seed=1),
        robust=RobustConfig(rule="phocas", b=6, q=6),
        attack=AttackConfig(name="slowburn", num_byzantine=6,
                            slowburn_trigger=10),
        defense=DefenseConfig(),
        num_workers=M, steps=25, log_every=1)
    res = run_experiment(spec)
    pre = [r for r in res.history if "loss" in r and r["step"] < 10]
    post = [r for r in res.history if "loss" in r and r["step"] >= 20]
    # phase 1: undetected and fully trusted (that IS the attack)
    assert all(r["q_hat"] == 0 for r in pre), pre
    assert all(r["n_active"] == M for r in pre), pre
    # phase 2: detected and ejected once the EMA lag is paid
    assert all(r["q_hat"] == 6 for r in post), post
    active = np.asarray(res.defense_state["active"])
    assert active[:6].sum() == 0, active       # colluders ejected
    assert active[6:].sum() == M - 6, active   # benign workers untouched
    # the strike itself stayed contained: phocas b=6 trims all 6 rows
    assert all(np.isfinite(r["loss"]) for r in res.history if "loss" in r)


# ---------------------------------------------------------------------------
# adapt_b: detector q̂ -> rule parameters (ROADMAP item a, satellite)
# ---------------------------------------------------------------------------

def test_adapt_b_recovers_underprovisioned_phocas():
    """Phocas launched with b=1 against q=6 signflip workers fails hard;
    with DefenseConfig.adapt_b the online q̂ raises b mid-run (1 -> 6) and
    training recovers.  The ejection gate is disabled (eject_below=0) in
    BOTH arms so the measured effect is the b/q re-tuning alone."""
    import dataclasses
    from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                                  run_experiment)
    base = ScenarioSpec(
        name="adapt", topology="sync_ps",
        model=ModelSpec(kind="mlp", dims=(64, 64, 10)),
        data=DataSpec(kind="classification", dim=64, batch_per_worker=20,
                      seed=1),
        robust=RobustConfig(rule="phocas", b=1, q=1),
        attack=AttackConfig(name="signflip", num_byzantine=6),
        num_workers=M, steps=50, log_every=10)
    common = dict(eject_below=0.0, detector_min_gap=0.05)
    adaptive = run_experiment(dataclasses.replace(
        base, defense=DefenseConfig(adapt_b=True, adapt_patience=1,
                                    **common)))
    fixed = run_experiment(dataclasses.replace(
        base, defense=DefenseConfig(**common)))
    assert adaptive.robust_cfg.b == 6, adaptive.robust_cfg
    events = [r for r in adaptive.history if "adapted_b" in r]
    assert events and events[-1]["adapted_b"] == 6, events
    assert fixed.robust_cfg.b == 1
    assert adaptive.final_eval > 0.9, adaptive.final_eval
    assert fixed.final_eval < 0.5, fixed.final_eval
    assert adaptive.final_eval - fixed.final_eval > 0.4


def test_adapt_b_noop_on_clean_run():
    """No attack -> q̂ stays 0 -> no adaptation, no re-jit."""
    from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                                  run_experiment)
    spec = ScenarioSpec(
        name="adapt-clean", topology="sync_ps",
        model=ModelSpec(kind="mlp", dims=(32, 32, 10)),
        data=DataSpec(kind="classification", dim=32, batch_per_worker=8),
        robust=RobustConfig(rule="phocas", b=2, q=2),
        defense=DefenseConfig(adapt_b=True),
        num_workers=M, steps=8, log_every=4)
    res = run_experiment(spec)
    assert res.robust_cfg.b == 2
    assert not any("adapted_b" in r for r in res.history)
