"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties
against the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops as kops
from repro.kernels.krum.ref import pairwise_sq_dists_ref
from repro.kernels.phocas.ref import phocas_ref
from repro.kernels.trmean.ref import trmean_ref

KEY = jax.random.PRNGKey(0)


def _valid_bs(m):
    return sorted(b for b in {1, 2, (m + 1) // 2 - 1}
                  if 1 <= b <= (m + 1) // 2 - 1)


def _assert_phocas_close(u, b, got, ref, atol=1e-4):
    """Phocas is discontinuous at distance ties (two values symmetric around
    the center): a 1-ulp center difference legitimately flips which value is
    dropped.  Mismatching coordinates must exhibit such a tie."""
    got, ref = np.asarray(got), np.asarray(ref)
    bad = np.where(np.abs(got - ref) > atol)[0]
    if bad.size == 0:
        return
    center = np.asarray(trmean_ref(u, b))
    for i in bad:
        d = np.sort(np.abs(np.asarray(u[:, i]) - center[i]))
        m = u.shape[0]
        boundary_gap = d[m - b] - d[m - b - 1]
        assert boundary_gap < 1e-4, (
            f"coord {i}: err {abs(got[i] - ref[i])} without a boundary tie "
            f"(gap {boundary_gap})")


@pytest.mark.parametrize("m", [4, 5, 20, 32, 64])
@pytest.mark.parametrize("d", [1, 100, 2048, 5000])
def test_trmean_kernel_sweep(m, d):
    u = 10 * jax.random.normal(jax.random.fold_in(KEY, m * d), (m, d))
    for b in _valid_bs(m):
        np.testing.assert_allclose(kops.trmean(u, b), trmean_ref(u, b),
                                   atol=1e-4, err_msg=f"b={b}")


@pytest.mark.parametrize("m", [4, 5, 20, 32])
@pytest.mark.parametrize("d", [1, 100, 2048, 5000])
def test_phocas_kernel_sweep(m, d):
    u = 10 * jax.random.normal(jax.random.fold_in(KEY, m + d), (m, d))
    for b in _valid_bs(m):
        _assert_phocas_close(u, b, kops.phocas(u, b), phocas_ref(u, b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_kernel_dtypes(dtype):
    u = (10 * jax.random.normal(KEY, (16, 512))).astype(dtype)
    t = kops.trmean(u, 3)
    p = kops.phocas(u, 3)
    assert t.dtype == jnp.float32 and p.dtype == jnp.float32
    np.testing.assert_allclose(t, trmean_ref(u, 3), atol=1e-2)
    np.testing.assert_allclose(p, phocas_ref(u, 3), atol=1e-2)


@pytest.mark.parametrize("m,d", [(5, 100), (20, 2048), (32, 4096)])
def test_krum_gram_kernel(m, d):
    u = 10 * jax.random.normal(KEY, (m, d))
    ref = np.asarray(pairwise_sq_dists_ref(u))
    got = np.asarray(kops.pairwise_sq_dists(u))
    # Gram-trick cancellation scales with the squared norms
    np.testing.assert_allclose(got, ref, atol=1e-6 * ref.max() + 1e-3)


def test_krum_kernel_selects_same_vector():
    from repro.core import aggregators as agg
    u = jax.random.normal(KEY, (12, 777))
    u = u.at[3].set(40.0)
    np.testing.assert_allclose(kops.krum(u, 2), agg.krum(u, 2), atol=1e-5)
    np.testing.assert_allclose(kops.multikrum(u, 2), agg.multikrum(u, 2),
                               atol=1e-5)


def test_kernel_b_validation():
    with pytest.raises(ValueError):
        kops.trmean(jnp.ones((6, 8)), 3)
    with pytest.raises(ValueError):
        kops.phocas(jnp.ones((6, 8)), 4)


@given(st.integers(4, 33), st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_trmean_kernel_property(m, d, seed):
    u = 5 * jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    b = (m - 1) // 3
    if b == 0:
        return
    np.testing.assert_allclose(kops.trmean(u, b), trmean_ref(u, b), atol=1e-4)


@given(st.integers(4, 25), st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_phocas_kernel_property(m, d, seed):
    u = 5 * jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    b = (m - 1) // 3
    if b == 0:
        return
    np.testing.assert_allclose(kops.phocas(u, b), phocas_ref(u, b), atol=1e-4)


def test_kernel_with_duplicate_values_ties():
    """Exact ties at the keep/drop boundary must match the stable oracle."""
    u = jnp.array([[0.0, 2.0], [2.0, 0.0], [1.0, 1.0], [1.0, 1.0]])
    np.testing.assert_allclose(kops.phocas(u, 1), phocas_ref(u, 1), atol=1e-6)
    u2 = jnp.tile(jnp.array([[1.0], [1.0], [1.0], [2.0], [0.0]]), (1, 200))
    np.testing.assert_allclose(kops.trmean(u2, 2), trmean_ref(u2, 2),
                               atol=1e-6)
