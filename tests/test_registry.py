"""Registry round-trip tests: every registered rule × every registered
attack through the local engine, every rule × both collective layouts
through the distributed engine, and backend="pallas" vs backend="xla"
equivalence for every rule that declares a kernel."""
import dataclasses
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttackConfig, RobustConfig, aggregate_matrix,
                        aggregators, registry)

KEY = jax.random.PRNGKey(3)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# A small worker matrix every rule/attack combination can digest:
# m=12 workers, q=2 Byzantine, b=2 trim.
M, D, B, Q = 12, 37, 2, 2


def _cfg(rule, attack="none", **kw):
    return RobustConfig(rule=rule, b=B, q=Q,
                        attack=AttackConfig(name=attack, num_byzantine=Q),
                        **kw)


# ---------------------------------------------------------------------------
# Registration surface
# ---------------------------------------------------------------------------

def test_builtin_and_plugin_rules_registered():
    rules = registry.available_rules()
    for name in ("mean", "median", "trmean", "phocas", "krum", "multikrum",
                 "geomedian", "mediam", "mom"):   # incl. single-file plugins
        assert name in rules, name
    assert set(registry.coordinate_wise_rules()) | \
        set(registry.vector_wise_rules()) == set(rules)


def test_plugin_rules_reach_every_lookup_surface():
    """mediam/mom must appear wherever the stack enumerates rules."""
    # get_aggregator-equivalent lookup
    u = jax.random.normal(KEY, (M, D))
    out = aggregators.get_aggregator("mediam", b=B)(u)
    assert out.shape == (D,)
    # benchmark sweeps enumerate benchmarks.common.RULES
    sys.path.insert(0, REPO)
    try:
        from benchmarks.common import ATTACKS, RULES
    finally:
        sys.path.pop(0)
    assert "mediam" in RULES and "mom" in RULES
    assert set(registry.available_attacks()) <= set(ATTACKS)


def test_unknown_rule_and_attack_errors_list_available():
    with pytest.raises(ValueError, match="phocas"):
        registry.get_rule("nope")
    with pytest.raises(ValueError, match="gambler"):
        registry.get_attack_spec("nope")


def test_duplicate_registration_rejected():
    class Dup(registry.AggregatorRule):
        name = "phocas"

        def _reduce_xla(self, u):
            return u

    with pytest.raises(ValueError, match="already registered"):
        registry.register_rule(Dup)


# ---------------------------------------------------------------------------
# Rule × attack matrix through the local engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", registry.available_rules())
@pytest.mark.parametrize("attack",
                         ("none",) + registry.available_attacks())
def test_rule_times_attack_roundtrip(rule, attack):
    u = 1.0 + 0.1 * jax.random.normal(KEY, (M, D))
    out = np.asarray(aggregate_matrix(u, _cfg(rule, attack), key=KEY))
    assert out.shape == (D,)
    resilient = registry.get_rule(rule).resilience == "dimensional"
    kind = (registry.get_attack_spec(attack).kind
            if attack != "none" else None)
    if attack == "none" or (resilient and kind == "dimensional"):
        # dimensional rules shrug off dimensional attacks: stay near g=1
        assert np.isfinite(out).all()
        assert np.abs(out - 1.0).max() < 1.0, (rule, attack, out.max())


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", registry.kernel_rules())
def test_backend_pallas_matches_xla(rule):
    u = 5 * jax.random.normal(KEY, (20, 257))
    ref = aggregate_matrix(u, _cfg(rule, backend="xla"))
    got = aggregate_matrix(u, _cfg(rule, backend="pallas"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-3)


def test_backend_pallas_on_kernel_less_rule_raises():
    with pytest.raises(ValueError, match="declares no"):
        aggregate_matrix(jnp.ones((8, 4)), _cfg("median", backend="pallas"))


def test_backend_auto_resolves_from_declared_kernels():
    assert registry.resolve_backend(registry.get_rule("median"), "auto") == "xla"
    expected = "xla" if jax.default_backend() == "cpu" else "pallas"
    assert registry.resolve_backend(
        registry.get_rule("trmean"), "auto") == expected


def test_use_kernels_deprecated_alias():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert RobustConfig(use_kernels=True).backend == "pallas"
        assert RobustConfig(use_kernels=False).backend == "xla"
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    # dataclasses.replace keeps the resolved backend
    cfg = dataclasses.replace(RobustConfig(backend="pallas"), rule="trmean")
    assert cfg.backend == "pallas"


# ---------------------------------------------------------------------------
# Parameter threading through RobustConfig
# ---------------------------------------------------------------------------

def test_multikrum_k_threads_through_config():
    u = jax.random.normal(KEY, (M, D))
    got = aggregate_matrix(u, _cfg("multikrum", multikrum_k=1))
    ref = aggregators.multikrum(u, q=Q, k=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    # and k=1 differs from the default k=m-q-2 (a mean over 8 candidates)
    dflt = aggregate_matrix(u, _cfg("multikrum"))
    assert np.abs(np.asarray(got) - np.asarray(dflt)).max() > 1e-4


def test_geomedian_iters_threads_through_config():
    u = jnp.concatenate([jnp.zeros((9, 5)), jnp.full((3, 5), 100.0)])
    coarse = np.asarray(aggregate_matrix(u, _cfg("geomedian",
                                                 geomedian_iters=1)))
    fine = np.asarray(aggregate_matrix(u, _cfg("geomedian",
                                               geomedian_iters=64)))
    assert np.abs(coarse - fine).max() > 1e-3      # iteration count matters
    ref = np.asarray(aggregators.geomedian(u, iters=64))
    np.testing.assert_allclose(fine, ref, atol=1e-5)


# ---------------------------------------------------------------------------
# Distributed round-trip: every rule through both layouts via the registry
# ---------------------------------------------------------------------------

DIST_ROUNDTRIP = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import (RobustConfig, AttackConfig, robust_aggregate_dist,
                        aggregate_matrix, registry)
from jax.flatten_util import ravel_pytree

mesh = jax.make_mesh((4, 2), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
key = jax.random.PRNGKey(1)
base = 2.0 + 0.1*jax.random.normal(key, (4, 67))
base = base.at[3].set(50.0)
grads = {'w': base[:, :64], 'b': base[:, 64:]}
mat = np.stack([ravel_pytree(jax.tree.map(lambda x: x[i], grads))[0]
                for i in range(4)])
results = {}
for rule in registry.available_rules():
    ref = aggregate_matrix(jnp.asarray(mat), RobustConfig(rule=rule, b=1, q=1))
    for layout in ['replicated', 'sharded']:
        cfg = RobustConfig(rule=rule, b=1, q=1, layout=layout)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P('data'),),
                 out_specs=P(), check_vma=False)
        def f(g):
            local = jax.tree.map(lambda x: x[0], g)
            return robust_aggregate_dist(local, cfg, worker_axes=('data',),
                                         model_axes=('model',))
        flat = ravel_pytree(f(grads))[0]
        results[f'{rule}/{layout}'] = bool(
            np.allclose(np.asarray(flat), np.asarray(ref), atol=1e-4))

# attack smoke through both layouts with a plugin rule: finite output
for layout in ['replicated', 'sharded']:
    cfg = RobustConfig(rule='mediam', b=1, layout=layout,
                       attack=AttackConfig(name='gaussian', num_byzantine=1))
    @partial(jax.shard_map, mesh=mesh, in_specs=(P('data'), P()),
             out_specs=P(), check_vma=False)
    def g(g_, k):
        local = jax.tree.map(lambda x: x[0], g_)
        return robust_aggregate_dist(local, cfg, worker_axes=('data',),
                                     model_axes=('model',), key=k)
    flat = ravel_pytree(g(grads, key))[0]
    results[f'mediam+gaussian/{layout}'] = bool(
        np.isfinite(np.asarray(flat)).all())
print(json.dumps(results))
"""


@pytest.mark.slow
def test_registry_rules_distributed_roundtrip():
    """Every registered rule (coordinate- AND vector-wise, plugins included)
    reproduces the single-host oracle through both collective layouts; the
    vector-wise rules exercise their ``reduce_sharded`` psum hooks."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", DIST_ROUNDTRIP],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(results) == 2 * len(registry.available_rules()) + 2
    bad = [k for k, v in results.items() if not v]
    assert not bad, bad


# ---------------------------------------------------------------------------
# Streaming capability flag
# ---------------------------------------------------------------------------

def test_streaming_gate_is_registry_driven():
    from repro.models.mlp import build_mlp_model
    from repro.optim import OptConfig
    from repro.train.streaming import make_streaming_train_step
    model = build_mlp_model(dims=(8, 8, 4))
    with pytest.raises(ValueError, match="supports_streaming"):
        make_streaming_train_step(
            model, robust_cfg=RobustConfig(rule="mediam", b=1),
            opt_cfg=OptConfig(lr=0.1), num_workers=4)
