"""Streaming robust aggregation == batch rules, exactly (beyond-paper mode
for models too large to hold m per-worker gradients)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AttackConfig, RobustConfig
from repro.data import ClassificationData, make_worker_batches
from repro.models.mlp import build_mlp_model, mlp_accuracy
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step
from repro.train.streaming import make_streaming_train_step

M, DIM = 8, 32
KEY = jax.random.PRNGKey(0)


def _setup(rule, attack=AttackConfig(), b=2):
    data = ClassificationData(num_classes=10, dim=DIM, noise=0.8, seed=1)
    model = build_mlp_model(dims=(DIM, 32, 10))
    params = model.init(KEY)
    opt_cfg = OptConfig(name="sgd", lr=0.1)
    rob = RobustConfig(rule=rule, b=b, q=b, attack=attack)
    opt_state = init_opt_state(opt_cfg, params)
    batch = make_worker_batches(data.batch(0, 16 * M), M)
    return data, model, params, opt_cfg, rob, opt_state, batch


@pytest.mark.parametrize("rule", ["mean", "trmean", "phocas"])
def test_streaming_equals_batch(rule):
    """One step of streaming mode == one step of vmap mode (clean)."""
    data, model, params, opt_cfg, rob, opt_state, batch = _setup(rule)
    s_batch = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                              num_workers=M, mesh=None, donate=False)
    s_stream = make_streaming_train_step(model, robust_cfg=rob,
                                         opt_cfg=opt_cfg, num_workers=M)
    p1, _, m1 = s_batch(params, opt_state, batch, KEY)
    p2, _, m2 = s_stream(params, opt_state, batch, KEY)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_streaming_memory_structure():
    """The streaming step's stats are O(b), not O(m): verified structurally —
    the jaxpr holds no (m, |θ|)-shaped intermediate."""
    data, model, params, opt_cfg, rob, opt_state, batch = _setup("phocas")
    step = make_streaming_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                                     num_workers=M)
    jaxpr = jax.make_jaxpr(
        lambda p, o, bt, k: step.__wrapped__(p, o, bt, k))(
        params, opt_state, batch, KEY)
    nparams = sum(x.size for x in jax.tree.leaves(params))
    for eqn_var in jaxpr.jaxpr.eqns:
        for v in eqn_var.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                if v.aval.shape and v.aval.shape[0] == M:
                    # worker-stacked full-gradient tensors must not exist
                    rest = 1
                    for d in v.aval.shape[1:]:
                        rest *= d
                    assert rest < nparams, v.aval.shape


def test_streaming_resists_gaussian_attack():
    rob_attack = AttackConfig(name="gaussian", num_byzantine=2)
    data, model, params, opt_cfg, rob, opt_state, batch = _setup(
        "trmean", rob_attack)
    step = make_streaming_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                                     num_workers=M)
    key = jax.random.PRNGKey(5)
    for i in range(40):
        batch = make_worker_batches(data.batch(i, 16 * M), M)
        params, opt_state, mt = step(params, opt_state, batch,
                                     jax.random.fold_in(key, i))
    acc = float(mlp_accuracy(params, data.test_set(512)))
    assert np.isfinite(float(mt["loss"]))
    assert acc > 0.6, acc


def test_streaming_rejects_unsupported():
    data, model, params, opt_cfg, rob, opt_state, batch = _setup("mean")
    with pytest.raises(ValueError):
        make_streaming_train_step(
            model, robust_cfg=RobustConfig(rule="krum"),
            opt_cfg=opt_cfg, num_workers=M)
