"""Multi-device tests: spawned subprocesses with fake host devices (the main
pytest process keeps the default 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


DIST_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import RobustConfig, robust_aggregate_dist, aggregate_matrix
from jax.flatten_util import ravel_pytree

mesh = jax.make_mesh((4, 2), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
key = jax.random.PRNGKey(1)
base = 2.0 + 0.1*jax.random.normal(key, (4, 67))
base = base.at[3].set(50.0)
grads = {'w': base[:, :64], 'b': base[:, 64:]}
mat = np.stack([ravel_pytree(jax.tree.map(lambda x: x[i], grads))[0]
                for i in range(4)])
results = {}
for rule in ['trmean','phocas','mean','median','krum','multikrum','geomedian']:
    ref = aggregate_matrix(jnp.asarray(mat), RobustConfig(rule=rule, b=1, q=1))
    for layout in ['replicated','sharded']:
        cfg = RobustConfig(rule=rule, b=1, q=1, layout=layout)
        @partial(jax.shard_map, mesh=mesh, in_specs=(P('data'),),
                 out_specs=P(), check_vma=False)
        def f(g):
            local = jax.tree.map(lambda x: x[0], g)
            return robust_aggregate_dist(local, cfg, worker_axes=('data',),
                                         model_axes=('model',))
        flat = ravel_pytree(f(grads))[0]
        results[f'{rule}/{layout}'] = bool(
            np.allclose(np.asarray(flat), np.asarray(ref), atol=1e-4))
print(json.dumps(results))
"""


def test_distributed_aggregation_equivalence():
    """Both collective layouts reproduce the single-host oracle for every
    rule (incl. Krum's psum'd distances and distributed Weiszfeld)."""
    out = run_sub(DIST_EQUIV)
    results = json.loads(out.strip().splitlines()[-1])
    bad = [k for k, v in results.items() if not v]
    assert not bad, bad


DIST_TRAIN = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_arch
from repro.models import build_model
from repro.core import RobustConfig, AttackConfig
from repro.optim import OptConfig, init_opt_state
from repro.data import TokenStream, make_worker_batches
from repro.train import make_train_step, step as step_mod
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=4, model=2)
cfg = get_arch('granite-8b-reduced')
model = build_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(key)
params = step_mod.shard_params(params, mesh) if hasattr(step_mod, 'shard_params') else params
opt_cfg = OptConfig(name='sgd', lr=0.2)
rob = RobustConfig(rule='phocas', b=1, layout='sharded',
                   attack=AttackConfig(name='gaussian', num_byzantine=1))
step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                       num_workers=4, mesh=mesh, donate=False)
opt_state = init_opt_state(opt_cfg, params)
ds = TokenStream(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=0)
losses = []
for i in range(8):
    batch = make_worker_batches(ds.batch(i), 4)
    params, opt_state, mt = step(params, opt_state, batch,
                                 jax.random.fold_in(key, i))
    losses.append(float(mt['loss']))
print(json.dumps({'first': losses[0], 'last': losses[-1],
                  'finite': all(np.isfinite(losses))}))
"""


def test_distributed_train_step_on_mesh():
    """Full train step on a 4×2 (data, model) mesh with attack injection:
    loss finite and decreasing."""
    out = run_sub(DIST_TRAIN.replace("from repro.train import make_train_step, step as step_mod",
                                     "from repro.train import make_train_step\nfrom repro.train import step as step_mod"))
    res = json.loads(out.strip().splitlines()[-1])
    assert res["finite"]
    assert res["last"] < res["first"], res


MULTIPOD = r"""
import os
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import RobustConfig, aggregate_matrix, robust_aggregate_dist
from jax.flatten_util import ravel_pytree

mesh = jax.make_mesh((2, 4, 2), ('pod', 'data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
key = jax.random.PRNGKey(1)
m = 8
mat_tree = {'w': jax.random.normal(key, (m, 48)),
            'b': jnp.arange(m*4, dtype=jnp.float32).reshape(m, 4)}
mat = np.stack([ravel_pytree(jax.tree.map(lambda x: x[i], mat_tree))[0]
                for i in range(m)])
ok = {}
for layout in ['replicated', 'sharded']:
    cfg = RobustConfig(rule='trmean', b=2, layout=layout)
    ref = aggregate_matrix(jnp.asarray(mat), cfg)
    @partial(jax.shard_map, mesh=mesh, in_specs=(P(('pod','data')),),
             out_specs=P(), check_vma=False)
    def f(g):
        local = jax.tree.map(lambda x: x[0], g)
        return robust_aggregate_dist(local, cfg,
                                     worker_axes=('pod', 'data'),
                                     model_axes=('model',))
    flat = ravel_pytree(f(mat_tree))[0]
    ok[layout] = bool(np.allclose(np.asarray(flat), np.asarray(ref), atol=1e-4))
print(json.dumps(ok))
"""


def test_multipod_worker_axes():
    """Robust aggregation over the joint (pod, data) worker axes — proves the
    pod axis participates in both layouts (incl. the 2-stage all_to_all)."""
    out = run_sub(MULTIPOD, devices=16)
    res = json.loads(out.strip().splitlines()[-1])
    assert res == {"replicated": True, "sharded": True}, res


@pytest.mark.slow
def test_dryrun_one_pair_compiles():
    """The dry-run entry point itself (512 fake devices, production mesh)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma2-2b",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
