"""Attack-suite tests (paper §5 adversaries)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as A

KEY = jax.random.PRNGKey(7)


def test_gaussian_replaces_q_rows():
    u = jnp.ones((20, 64))
    out = A.gaussian_attack(KEY, u, q=6, std=200.0)
    assert not np.allclose(out[:6], 1.0)
    np.testing.assert_allclose(out[6:], 1.0)
    assert float(jnp.std(out[:6])) > 50.0     # std-200 noise


def test_omniscient_negative_sum():
    u = jnp.ones((10, 8))
    out = A.omniscient_attack(KEY, u, q=3, scale=1e20)
    np.testing.assert_allclose(out[0], -1e20 * 7 * np.ones(8))
    np.testing.assert_allclose(out[3:], 1.0)


def test_bitflip_exact_bits():
    # Bits 22,30,31,32 (1-indexed from LSB): mantissa-21, exponent 29/30, sign
    x = jnp.full((1, 1), 1.0, jnp.float32)
    out = A._flip_bits_f32(x, (22, 30, 31, 32))
    xi = np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))[0, 0]
    oi = np.asarray(jax.lax.bitcast_convert_type(out, jnp.uint32))[0, 0]
    assert xi ^ oi == (1 << 21) | (1 << 29) | (1 << 30) | (1 << 31)
    # the corruption is destructive (sign + high exponent)
    assert float(out[0, 0]) < -1e18
    # flipping twice restores
    back = A._flip_bits_f32(out, (22, 30, 31, 32))
    np.testing.assert_allclose(back, x)


def test_bitflip_q_per_dimension():
    m, d, q, nd = 20, 500, 1, 100
    u = jax.random.normal(KEY, (m, d))
    out = A.bitflip_attack(KEY, u, q=q, num_dims=nd)
    changed = np.asarray(out != u)
    assert (changed[:, :nd].sum(0) == q).all()   # exactly q per attacked dim
    assert not changed[:, nd:].any()              # rest untouched


def test_gambler_hits_one_server_slice():
    m, d, servers = 20, 2000, 20
    u = jnp.ones((m, d))
    # raise prob so the test is deterministic-ish
    out = A.gambler_attack(KEY, u, num_servers=servers, prob=0.2,
                           scale=-1e20)
    changed = np.asarray(out != u)
    ssize = d // servers
    assert changed[:, :ssize].any()               # attacked server slice
    assert not changed[:, ssize:].any()           # others clean


def test_make_attack_dispatch_and_none():
    assert A.make_attack(A.AttackConfig(name="none")) is None
    cfg = A.AttackConfig(name="signflip", num_byzantine=2)
    atk = A.make_attack(cfg)
    u = jnp.ones((5, 3))
    out = atk(KEY, u)
    np.testing.assert_allclose(out[:2], -10.0)


def test_zero_attack():
    u = jnp.ones((6, 4))
    out = A.zero_attack(KEY, u, q=2)
    np.testing.assert_allclose(out[:2], 0.0)
    np.testing.assert_allclose(out[2:], 1.0)
