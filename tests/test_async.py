"""Asynchronous Byzantine-resilient SGD (paper future work, §7):
staleness + dimensional attacks, Phocas survives where Mean fails."""
import jax
import numpy as np
import pytest

from repro.core import AttackConfig, RobustConfig
from repro.data import ClassificationData
from repro.models.mlp import build_mlp_model, mlp_accuracy
from repro.optim import OptConfig
from repro.train.async_sgd import AsyncConfig, run_async_training

M, DIM = 20, 64


def _run(rule, attack, staleness=4, steps=60, b=6):
    data = ClassificationData(num_classes=10, dim=DIM, noise=0.8, seed=1)
    model = build_mlp_model(dims=(DIM, 64, 10))
    rob = RobustConfig(rule=rule, b=b, q=b, attack=attack)
    acfg = AsyncConfig(num_workers=M, staleness=staleness)
    test = data.test_set(1024)
    hist = run_async_training(
        model, lambda i: data.batch(i, 20 * M), rob,
        OptConfig(name="sgd", lr=0.1), acfg, steps,
        eval_fn=lambda p: mlp_accuracy(p, test))
    return hist[-1]["eval"]


def test_async_clean_converges_despite_staleness():
    acc = _run("mean", AttackConfig(name="none"), staleness=6)
    assert acc > 0.9, acc


def test_async_phocas_survives_bitflip():
    attack = AttackConfig(name="bitflip", num_byzantine=1)
    acc_phocas = _run("phocas", attack, b=8)
    acc_mean = _run("mean", attack, b=8, steps=30)
    assert acc_phocas > 0.85, acc_phocas
    assert acc_mean < 0.5 or not np.isfinite(acc_mean), acc_mean


def test_async_trmean_survives_omniscient():
    attack = AttackConfig(name="omniscient", num_byzantine=6)
    acc = _run("trmean", attack)
    assert acc > 0.8, acc


@pytest.mark.parametrize("staleness", [1, 8])
def test_async_staleness_degrades_gracefully(staleness):
    """More staleness = slower but still-converging robust training."""
    acc = _run("phocas", AttackConfig(name="gaussian", num_byzantine=6),
               staleness=staleness)
    assert acc > 0.75, (staleness, acc)
