"""Unit + property tests for the paper's aggregation rules.

The hypothesis-based property tests are optional: on minimal installs
without ``hypothesis`` they are skipped and the rest of the module still
collects and runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (aggregators as agg, bounds)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Oracles
# ---------------------------------------------------------------------------

def np_trmean(u, b):
    s = np.sort(u, axis=0)
    m = u.shape[0]
    return s[b:m - b].mean(0)


class TestTrmean:
    def test_matches_numpy(self):
        u = np.asarray(jax.random.normal(KEY, (20, 257)))
        for b in (0, 1, 4, 9):
            np.testing.assert_allclose(agg.trmean(jnp.asarray(u), b),
                                       np_trmean(u, b), atol=1e-5)

    def test_b0_is_mean(self):
        u = jax.random.normal(KEY, (7, 11))
        np.testing.assert_allclose(agg.trmean(u, 0), agg.mean(u), atol=1e-6)

    def test_b_range_validation(self):
        u = jnp.ones((6, 3))
        with pytest.raises(ValueError):
            agg.trmean(u, 3)          # ceil(6/2)-1 = 2 is max

    def test_max_b_is_median_odd_m(self):
        u = jax.random.normal(KEY, (9, 33))
        np.testing.assert_allclose(agg.trmean(u, 4), agg.median(u), atol=1e-6)


class TestPhocas:
    def test_keeps_m_minus_b_nearest(self):
        # hand example: m=4, b=1; trmean drops 2/0, center=(1+1)/2=1
        u = jnp.array([[0.0], [1.0], [1.0], [10.0]])
        # dists to 1: [1,0,0,9] -> drop 10 -> mean(0,1,1)=2/3
        np.testing.assert_allclose(agg.phocas(u, 1), [2.0 / 3], atol=1e-6)

    def test_b0_is_mean(self):
        u = jax.random.normal(KEY, (7, 11))
        np.testing.assert_allclose(agg.phocas(u, 0), agg.mean(u), atol=1e-6)

    def test_agrees_with_kernel_ref(self):
        from repro.kernels.phocas.ref import phocas_ref
        u = jax.random.normal(KEY, (20, 100))
        np.testing.assert_allclose(agg.phocas(u, 5), phocas_ref(u, 5),
                                   atol=1e-5)


class TestKrum:
    def test_selects_inlier(self):
        u = np.tile(np.linspace(0, 1, 64), (10, 1)).astype(np.float32)
        u += 0.01 * np.asarray(jax.random.normal(KEY, u.shape))
        u[0] = 100.0                            # outlier
        out = agg.krum(jnp.asarray(u), q=1)
        assert np.abs(np.asarray(out) - u[1:].mean(0)).max() < 1.0

    def test_output_is_a_candidate(self):
        u = jax.random.normal(KEY, (8, 13))
        out = np.asarray(agg.krum(u, q=2))
        assert any(np.allclose(out, np.asarray(u[i])) for i in range(8))

    def test_q_validation(self):
        with pytest.raises(ValueError):
            agg.krum(jnp.ones((5, 3)), q=3)

    def test_multikrum_mean_of_selected(self):
        u = jax.random.normal(KEY, (10, 7))
        out = agg.multikrum(u, q=2, k=10 - 2 - 2)
        assert out.shape == (7,)


class TestGeomedian:
    def test_resists_outlier(self):
        u = np.zeros((9, 5), np.float32)
        u[:8] = 1.0
        u[8] = 1e6
        out = np.asarray(agg.geomedian(jnp.asarray(u)))
        assert np.abs(out - 1.0).max() < 0.1


# ---------------------------------------------------------------------------
# Dimensional-resilience properties (hypothesis)
# ---------------------------------------------------------------------------

@st.composite
def byz_matrices(draw):
    m = draw(st.integers(4, 24))
    d = draw(st.integers(1, 40))
    q = draw(st.integers(0, (m - 1) // 2))     # 2q < m
    b = draw(st.integers(q, max(q, (m + 1) // 2 - 1)))
    seed = draw(st.integers(0, 2**31 - 1))
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (m, d))
    # generalized Byzantine: q arbitrary values PER DIMENSION corrupted
    scores = jax.random.uniform(k2, (m, d))
    ranks = jnp.argsort(jnp.argsort(scores, axis=0), axis=0)
    hit = ranks < q
    byz = 1e8 * jax.random.normal(k3, (m, d))
    return np.asarray(u), np.asarray(jnp.where(hit, byz, u)), q, b


@given(byz_matrices())
@settings(max_examples=60, deadline=None)
def test_trmean_dimensional_resilience(data):
    """Lemma 2 consequence: with b >= q corrupted per dimension, the trimmed
    mean stays within the correct values' range per coordinate."""
    u, tilde, q, b = data
    if b > (u.shape[0] + 1) // 2 - 1:
        return
    out = np.asarray(agg.trmean(jnp.asarray(tilde), b))
    lo, hi = u.min(0), u.max(0)
    assert (out >= lo - 1e-4).all() and (out <= hi + 1e-4).all()


@given(byz_matrices())
@settings(max_examples=60, deadline=None)
def test_phocas_dimensional_resilience(data):
    """Kept values are within max-correct-distance of the trimmed mean, so
    Phocas lands in [2lo - hi, 2hi - lo] per coordinate (Lemma 3)."""
    u, tilde, q, b = data
    if b > (u.shape[0] + 1) // 2 - 1:
        return
    out = np.asarray(agg.phocas(jnp.asarray(tilde), b))
    lo, hi = u.min(0), u.max(0)
    span = hi - lo
    assert (out >= lo - span - 1e-3).all() and (out <= hi + span + 1e-3).all()


@given(st.integers(5, 30), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_coordinate_wise_rules_permutation_invariant(m, seed):
    ku, kp = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(ku, (m, 8))
    perm = jax.random.permutation(kp, m)
    b = (m - 1) // 3
    for rule in (lambda x: agg.trmean(x, b), lambda x: agg.phocas(x, b),
                 agg.median, agg.mean):
        np.testing.assert_allclose(rule(u), rule(u[perm]), atol=1e-5)


# ---------------------------------------------------------------------------
# Negative results (Propositions 1-3)
# ---------------------------------------------------------------------------

def test_proposition1_mean_not_resilient():
    """One corrupted value per dimension drives the mean anywhere."""
    m, d = 10, 4
    u = jnp.ones((m, d))
    target = -1e6
    tilde = u.at[0].set(m * target - (m - 1))
    out = agg.mean(tilde)
    assert float(jnp.max(out)) < -1e5       # arbitrarily bad
    # while trmean with b>=1 is unaffected:
    np.testing.assert_allclose(agg.trmean(tilde, 1), np.ones(d), atol=1e-5)


def test_proposition2_selection_rules_fail_dimensionally():
    """Prop 2 counterexample: corrupt dimension i of vector i — any rule that
    outputs one of its inputs returns a corrupted coordinate."""
    m = 6
    u = jnp.ones((m, m))
    tilde = u + jnp.diag(jnp.full((m,), -1e9))
    out = np.asarray(agg.krum(tilde, q=1))
    assert out.min() < -1e8                 # Krum output contains a Byz value
    out2 = np.asarray(agg.trmean(tilde, 1))
    np.testing.assert_allclose(out2, np.ones(m), atol=1e-4)  # Trmean fine


# ---------------------------------------------------------------------------
# Variance bounds (Theorems 1-2), Monte-Carlo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule,delta_fn", [
    ("trmean", bounds.delta_trmean), ("phocas", bounds.delta_phocas)])
def test_variance_bound_montecarlo(rule, delta_fn):
    m, d, q, b, trials = 20, 50, 3, 6, 200
    V = float(d)                             # per-coordinate unit variance
    delta = delta_fn(m, q, b, V)
    fn = agg.get_aggregator(rule, b=b)
    key = jax.random.PRNGKey(42)
    errs = []
    for t in range(trials):
        k1, k2, _ = jax.random.split(jax.random.fold_in(key, t), 3)
        u = jax.random.normal(k1, (m, d))    # g = 0
        scores = jax.random.uniform(k2, (m, d))
        ranks = jnp.argsort(jnp.argsort(scores, axis=0), axis=0)
        tilde = jnp.where(ranks < q, 1e6, u)  # adversarial per-dim corruption
        errs.append(float(jnp.sum(fn(tilde) ** 2)))
    assert np.mean(errs) <= delta, (np.mean(errs), delta)


def test_bounds_monotonicity():
    V = 1.0
    assert bounds.delta_trmean(40, 2, 4, V) < bounds.delta_trmean(20, 2, 4, V)
    assert bounds.delta_trmean(20, 2, 4, V) < bounds.delta_trmean(20, 2, 8, V)
    assert bounds.delta_phocas(20, 2, 4, V) > bounds.delta_trmean(20, 2, 4, V)
    with pytest.raises(ValueError):
        bounds.delta_trmean(10, 5, 5, V)     # 2q < m violated


# ---------------------------------------------------------------------------
# One-pass gated defense overrides (fused_gate) for the vector-wise rules
# ---------------------------------------------------------------------------

def _gated_setup(m=10, d=33, seed=7):
    from repro.core import registry
    ku, _ = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(ku, (m, d))
    u = u.at[0].set(50.0)                    # far outlier, soon ejected
    active = jnp.ones((m,)).at[0].set(0.0)
    return registry, u, active


@pytest.mark.parametrize("rule", ("krum", "multikrum"))
def test_krum_family_gated_override_matches_composed(rule):
    """The incremental gated-Gram one-pass hook is drop-in for the
    registry's two-pass composition (same selection, same scores)."""
    from repro.core.registry import AggregatorRule
    registry, u, active = _gated_setup()
    r = registry.make_rule(rule, registry.RuleParams(q=2, backend="xla"))
    got_agg, got_sc = r.reduce_gated_with_scores(u, active)
    ref_agg, ref_sc = AggregatorRule.reduce_sharded_gated_with_scores(
        r, u, active, ())
    np.testing.assert_allclose(np.asarray(got_sc), np.asarray(ref_sc),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_agg), np.asarray(ref_agg),
                               atol=1e-4)


@pytest.mark.parametrize("rule", ("krum", "multikrum", "geomedian"))
def test_vector_rule_gated_none_equals_ungated(rule):
    registry, u, _ = _gated_setup()
    r = registry.make_rule(rule, registry.RuleParams(q=2, backend="xla"))
    got_agg, got_sc = r.reduce_gated_with_scores(u, None)
    ref_agg, ref_sc = r.reduce_sharded_with_scores(u, ())
    np.testing.assert_allclose(np.asarray(got_agg), np.asarray(ref_agg),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_sc), np.asarray(ref_sc),
                               atol=1e-6)


def test_geomedian_gated_override_center_matches_composed():
    """One Weiszfeld run on the gated matrix == the composed path's gated
    aggregate; scores still observe the raw submissions (the ejected far
    row stays maximally suspicious — flap prevention)."""
    registry, u, active = _gated_setup()
    r = registry.make_rule("geomedian", registry.RuleParams(backend="xla"))
    got_z, got_sc = r.reduce_gated_with_scores(u, active)
    from repro.core.selection import gate_matrix
    ref_z = r.reduce_sharded(gate_matrix(u, active), ())
    np.testing.assert_allclose(np.asarray(got_z), np.asarray(ref_z),
                               atol=1e-5)
    sc = np.asarray(got_sc)
    assert sc.shape == (u.shape[0],) and np.isfinite(sc).all()
    assert (sc >= 0.0).all() and (sc <= 1.0).all()
    assert sc[0] == sc.max() and sc[0] > 0.5   # raw outlier still blamed


def test_fused_gate_metadata_matches_overrides():
    """fused_gate is the routing metadata CONTRACT007 enforces: True
    exactly for rules whose gated hook is a genuine override."""
    from repro.core.registry import AggregatorRule
    from repro.core import registry
    expected = set()
    for name in registry.available_rules():
        cls = registry.get_rule(name)
        own = cls.reduce_sharded_gated_with_scores \
            is not AggregatorRule.reduce_sharded_gated_with_scores
        assert cls.fused_gate == own, name
        if own:
            expected.add(name)
    assert set(registry.fused_gate_rules()) == expected
    assert {"krum", "multikrum", "geomedian"} <= expected
