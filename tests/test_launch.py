"""Launcher-level tests: Trainer loop, train/serve CLIs, HLO collective
accounting on a real multi-device program."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def test_trainer_loop_end_to_end(tmp_path):
    from repro.configs import get_arch
    from repro.core import AttackConfig, RobustConfig
    from repro.data import TokenStream
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.train import Trainer, TrainerConfig

    cfg = get_arch("granite-8b-reduced")
    model = build_model(cfg)
    ds = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    ckpt = str(tmp_path / "ck")
    tcfg = TrainerConfig(num_workers=4, steps=12, log_every=4,
                         checkpoint_path=ckpt, checkpoint_every=10)
    rob = RobustConfig(rule="trmean", b=1,
                       attack=AttackConfig(name="zero", num_byzantine=1))
    trainer = Trainer(model, ds.batch, tcfg, rob, OptConfig(lr=0.3))
    hist = trainer.run(verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert os.path.exists(ckpt + ".npz")        # checkpoint written
    # restore round-trips
    from repro.checkpoint import load_checkpoint
    restored, step = load_checkpoint(
        ckpt, {"params": trainer.params, "opt": trainer.opt_state})
    assert step == 10


@pytest.mark.slow
def test_train_cli():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "gemma2-2b-reduced", "--steps", "6", "--global-batch", "8",
         "--seq-len", "16", "--workers", "4", "--rule", "phocas", "--b",
         "1", "--attack", "gaussian", "--q", "1"],
        capture_output=True, text=True, env=ENV, timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[train] done" in out.stdout


@pytest.mark.slow
def test_serve_cli():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "granite-8b-reduced", "--batch", "2", "--prompt-len", "4",
         "--new-tokens", "4"],
        capture_output=True, text=True, env=ENV, timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout


def test_hlo_collectives_accounting_multidevice():
    """The analyzer's collective bytes match hand-computed values for a
    known 8-device psum program."""
    code = r"""
import jax, jax.numpy as jnp, json
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
mesh = jax.make_mesh((8,), ('d',), axis_types=(jax.sharding.AxisType.Auto,))
@partial(jax.shard_map, mesh=mesh, in_specs=P('d'), out_specs=P())
def f(x):
    return jax.lax.psum(x, 'd')
x = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
hlo = jax.jit(f).lower(x).compile().as_text()
t = analyze_hlo(hlo)
print(json.dumps({'ar': t['collective_bytes']['all-reduce'],
                  'total': t['collective_total_bytes']}))
"""
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # psum of a (1, 1024) f32 shard -> all-reduce output 4096 B per device
    assert res["ar"] == 4096, res
