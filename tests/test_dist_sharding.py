"""Unit tests for the ``repro.dist`` sharding API: mesh-role derivation and
PartitionSpec rules on 1-device, (data, model), and (pod, data, model)
meshes.  Multi-device meshes run in spawned subprocesses with fake host
devices (the main pytest process keeps the default 1 device)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import (cache_pspec, model_axes_of,
                                 param_pspec_fsdp, tree_pspecs,
                                 worker_axes_of)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int, timeout: int = 300) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def one_device_mesh() -> Mesh:
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ---------------------------------------------------------------------------
# In-process: 1-device mesh
# ---------------------------------------------------------------------------

def test_axis_roles_one_device():
    mesh = one_device_mesh()
    assert worker_axes_of(mesh) == ("data",)
    assert model_axes_of(mesh) == ("model",)


def test_tree_pspecs_one_device_all_replicated():
    """With a size-1 model axis nothing divides usefully: every leaf must be
    fully replicated (and the spec tree must mirror the input structure)."""
    mesh = one_device_mesh()
    tree = {"embed": {"table": jax.ShapeDtypeStruct((128, 64), "float32")},
            "stack": {"blocks": {"l0": {"mixer": {"wq": {
                "w": jax.ShapeDtypeStruct((2, 64, 32), "float32")}}}}},
            "final_norm": {"scale": jax.ShapeDtypeStruct((64,), "float32")}}
    specs = tree_pspecs(tree, mesh)
    assert jax.tree_util.tree_structure(specs, is_leaf=lambda x: isinstance(
        x, P)) == jax.tree_util.tree_structure(tree)
    for leaf in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert leaf == P()


def test_param_pspec_fsdp_one_device():
    mesh = one_device_mesh()
    leaf = jax.ShapeDtypeStruct((256, 64), "float32")
    assert param_pspec_fsdp("stack/w", leaf, mesh) == P()


def test_cache_pspec_one_device():
    mesh = one_device_mesh()

    class KeyEntry:
        def __init__(self, key):
            self.key = key

    leaf = jax.ShapeDtypeStruct((8, 16, 4, 32), "float32")
    spec = cache_pspec((KeyEntry("tail0"), KeyEntry("mixer"),
                        KeyEntry("k")), leaf, mesh)
    assert spec == P(None, None, None, None)


def test_leaf_rule_override():
    """leaf_rule wins when it returns a spec, falls through on None."""
    mesh = one_device_mesh()
    tree = {"a": {"w": jax.ShapeDtypeStruct((4, 4), "float32")},
            "b": {"w": jax.ShapeDtypeStruct((4, 4), "float32")}}
    marker = P(None, None)
    specs = tree_pspecs(tree, mesh, leaf_rule=lambda name, leaf, m:
                        marker if name.startswith("a") else None)
    assert specs["a"]["w"] == marker
    assert specs["b"]["w"] == P()


# ---------------------------------------------------------------------------
# Subprocess: (data, model) mesh — 8 devices
# ---------------------------------------------------------------------------

DATA_MODEL = r"""
import jax, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.dist.sharding import (model_axes_of, param_pspec_fsdp,
                                 tree_pspecs, worker_axes_of)
from repro.models import build_model

mesh = jax.make_mesh((4, 2), ('data', 'model'))
out = {'worker': worker_axes_of(mesh), 'model': model_axes_of(mesh)}

# Every model family: each leaf's spec must be constructible and divide.
def check_arch(arch):
    model = build_model(get_arch(arch))
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = tree_pspecs(shapes, mesh)
    n_sharded = 0
    for leaf, spec in zip(
            jax.tree_util.tree_leaves(shapes),
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P))):
        NamedSharding(mesh, spec)                    # must be constructible
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            group = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in group:
                size *= mesh.shape[a]
            assert leaf.shape[d] % size == 0, (arch, leaf.shape, spec)
            n_sharded += 1
    return n_sharded

counts = {a: check_arch(a) for a in
          ['granite-8b-reduced', 'deepseek-v2-lite-16b-reduced',
           'mamba2-2.7b-reduced', 'kimi-k2-1t-a32b-reduced']}
out['sharded_counts'] = counts

# FSDP rule: largest dim sharded over the joint (data, model) group.
leaf = jax.ShapeDtypeStruct((512, 24), 'float32')
out['fsdp'] = str(param_pspec_fsdp('x/w', leaf, mesh))
leaf2 = jax.ShapeDtypeStruct((7, 24), 'float32')     # 7 indivisible, 24 = 8*3
out['fsdp_fallback'] = str(param_pspec_fsdp('x/w', leaf2, mesh))
leaf3 = jax.ShapeDtypeStruct((7, 5), 'float32')      # nothing divides
out['fsdp_replicated'] = str(param_pspec_fsdp('x/w', leaf3, mesh))
print(json.dumps(out))
"""


def test_data_model_mesh_rules():
    res = json.loads(run_sub(DATA_MODEL, devices=8).strip().splitlines()[-1])
    assert res["worker"] == ["data"]
    assert res["model"] == ["model"]
    # every family must actually shard something under TP
    assert all(n > 0 for n in res["sharded_counts"].values()), res
    assert res["fsdp"] == str(P(("data", "model"), None))
    assert res["fsdp_fallback"] == str(P(None, ("data", "model")))
    assert res["fsdp_replicated"] == str(P())


# ---------------------------------------------------------------------------
# Subprocess: (pod, data, model) multi-pod mesh — 16 devices
# ---------------------------------------------------------------------------

MULTIPOD = r"""
import jax, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.sharding import (cache_pspec, model_axes_of,
                                 param_pspec_fsdp, tree_pspecs,
                                 worker_axes_of)

mesh = jax.make_mesh((2, 4, 2), ('pod', 'data', 'model'))
out = {'worker': worker_axes_of(mesh), 'model': model_axes_of(mesh)}

tree = {'embed': {'table': jax.ShapeDtypeStruct((128, 64), 'float32')},
        'blocks': {'l0': {'mixer': {
            'wq': {'w': jax.ShapeDtypeStruct((3, 64, 32), 'float32')},
            'wo': {'w': jax.ShapeDtypeStruct((3, 32, 64), 'float32')}},
            'ffn': {'moe_wi': jax.ShapeDtypeStruct((3, 4, 64, 16), 'float32'),
                    'moe_wo': jax.ShapeDtypeStruct((3, 4, 16, 64), 'float32')}}},
        'norm': {'scale': jax.ShapeDtypeStruct((64,), 'float32')}}
specs = tree_pspecs(tree, mesh)
for spec in jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)):
    NamedSharding(mesh, spec)
out['specs'] = {
    'table': str(specs['embed']['table']),
    'wq': str(specs['blocks']['l0']['mixer']['wq']['w']),
    'wo': str(specs['blocks']['l0']['mixer']['wo']['w']),
    'moe_wi': str(specs['blocks']['l0']['ffn']['moe_wi']),
    'moe_wo': str(specs['blocks']['l0']['ffn']['moe_wo']),
    'scale': str(specs['norm']['scale']),
}

# fsdp: joint (pod, data, model) group = 16-way
leaf = jax.ShapeDtypeStruct((64, 48), 'float32')
out['fsdp'] = str(param_pspec_fsdp('w', leaf, mesh))

# cache: batch over joint (pod, data) workers, KV heads over model
class KE:
    def __init__(self, key): self.key = key
kv = jax.ShapeDtypeStruct((16, 32, 4, 8), 'float32')
out['cache_tail'] = str(cache_pspec((KE('tail0'), KE('mixer'), KE('k')),
                                    kv, mesh))
kv_blocks = jax.ShapeDtypeStruct((3, 16, 32, 4, 8), 'float32')
out['cache_blocks'] = str(cache_pspec((KE('blocks'), KE('l0'), KE('mixer'),
                                       KE('v')), kv_blocks, mesh))
print(json.dumps(out))
"""


def test_multipod_mesh_rules():
    res = json.loads(run_sub(MULTIPOD, devices=16).strip().splitlines()[-1])
    assert res["worker"] == ["pod", "data"]
    assert res["model"] == ["model"]
    s = res["specs"]
    assert s["table"] == str(P("model", None))          # vocab sharded
    assert s["wq"] == str(P(None, None, "model"))       # column parallel
    assert s["wo"] == str(P(None, "model", None))       # row parallel
    assert s["moe_wi"] == str(P(None, None, None, "model"))
    assert s["moe_wo"] == str(P(None, None, "model", None))
    assert s["scale"] == str(P())
    assert res["fsdp"] == str(P(("pod", "data", "model"), None))
    assert res["cache_tail"] == str(P(("pod", "data"), None, "model", None))
    assert res["cache_blocks"] == str(
        P(None, ("pod", "data"), None, "model", None))
