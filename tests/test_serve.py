"""repro.serve v2 tests (DESIGN.md §11): paged KV cache vs dense ring
cache bit-equivalence, block-table alloc/free lifecycle, continuous
batching join/retire, batched-prefill regression, and replicated
Byzantine-robust decode (recovery + replica ejection)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import (BlockAllocator, OutOfBlocks, PagedKVCache, Request,
                         RobustDecoder, Scheduler, ServeEngine,
                         batched_prefill_supported, corrupt_replica,
                         generate, generate_stepwise, make_replicas)

ARCH = "granite-8b-reduced"


@pytest.fixture(scope="module")
def model_and_params():
    model = build_model(get_arch(ARCH))
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(n, lens, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, (lens[i % len(lens)],)).tolist()
            for i in range(n)]


# ---------------------------------------------------------------------------
# Block allocator / block-table lifecycle
# ---------------------------------------------------------------------------

class TestBlockAllocator:
    def test_block_zero_reserved(self):
        alloc = BlockAllocator(8)
        got = alloc.alloc(alloc.free_blocks)      # drain the pool
        assert 0 not in got
        assert sorted(got) == list(range(1, 8))

    def test_out_of_blocks(self):
        alloc = BlockAllocator(4)
        alloc.alloc(3)
        with pytest.raises(OutOfBlocks):
            alloc.alloc(1)

    def test_free_rejects_reserved_and_double_free(self):
        alloc = BlockAllocator(8)
        blocks = alloc.alloc(2)
        alloc.free(blocks)
        with pytest.raises(ValueError):
            alloc.free([blocks[0]])               # double free
        with pytest.raises(ValueError):
            alloc.free([0])                       # the null block

    def test_free_returns_capacity(self):
        alloc = BlockAllocator(8)
        blocks = alloc.alloc(7)
        assert alloc.free_blocks == 0
        alloc.free(blocks)
        assert alloc.free_blocks == 7


class TestPagedKVCacheLifecycle:
    def test_ensure_release_roundtrip(self, model_and_params):
        model, _ = model_and_params
        cache = PagedKVCache(model, max_slots=2, max_seq_len=32,
                             block_tokens=4)
        total = cache.allocator.free_blocks
        cache.ensure(0, 10)                       # 3 blocks of 4
        assert len(cache.owned_blocks(0)) == 3
        assert (cache.tables[0, :3] > 0).all()    # never the null block
        assert cache.tables[0, 3:].sum() == 0
        cache.ensure(0, 12)                       # still 3 blocks: no-op
        assert len(cache.owned_blocks(0)) == 3
        cache.ensure(0, 13)                       # grows to 4
        assert len(cache.owned_blocks(0)) == 4
        cache.release(0)
        assert cache.owned_blocks(0) == []
        assert cache.tables[0].sum() == 0
        assert cache.allocator.free_blocks == total

    def test_admission_gate(self, model_and_params):
        model, _ = model_and_params
        cache = PagedKVCache(model, max_slots=2, max_seq_len=32,
                             block_tokens=4, num_blocks=5)   # 4 usable
        assert cache.can_cover(16)
        assert not cache.can_cover(17)
        cache.ensure(0, 16)
        assert not cache.can_cover(1)
        with pytest.raises(OutOfBlocks):
            cache.ensure(1, 4)

    def test_beyond_table_capacity(self, model_and_params):
        model, _ = model_and_params
        cache = PagedKVCache(model, max_slots=1, max_seq_len=16,
                             block_tokens=4)
        with pytest.raises(OutOfBlocks):
            cache.ensure(0, 17)                   # > max_seq_len


# ---------------------------------------------------------------------------
# Batched prefill regression (dense path)
# ---------------------------------------------------------------------------

def test_batched_prefill_matches_stepwise(model_and_params):
    """generate()'s one-pass prefill must be bit-identical to the legacy
    token-by-token decode-path prefill."""
    model, params = model_and_params
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0,
                                 model.cfg.vocab_size)
    assert batched_prefill_supported(model.cfg, 5)
    new = generate(model, params, prompts, 6)
    old = generate_stepwise(model, params, prompts, 6)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


def test_windowed_arch_uses_fallback():
    cfg = get_arch("gemma3-27b-reduced")          # windowed layers
    assert not batched_prefill_supported(cfg, prompt_len=10**9)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    new = generate(model, params, prompts, 4)     # routes through stepwise
    old = generate_stepwise(model, params, prompts, 4)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


# ---------------------------------------------------------------------------
# Paged vs dense bit-equivalence
# ---------------------------------------------------------------------------

def test_paged_prefill_and_decode_match_dense(model_and_params):
    """Logits through the paged path (block tables, scatter/gather) equal
    the dense ring-cache path bit-for-bit at every step."""
    model, params = model_and_params
    B, S0, NEW = 3, 5, 4
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, S0), 0,
                                 model.cfg.vocab_size)

    dense = model.init_cache(B, S0 + NEW)
    d_logits, dense = model.decode_step(params, dense, prompts,
                                        jnp.arange(S0))

    cache = PagedKVCache(model, max_slots=B, max_seq_len=S0 + NEW,
                         block_tokens=4)
    for s in range(B):
        cache.ensure(s, S0 + NEW)
    tables = cache.device_tables()
    p_logits, pool = model.prefill_paged(params, cache.pool, prompts,
                                         tables)
    np.testing.assert_array_equal(np.asarray(d_logits),
                                  np.asarray(p_logits))

    tok = jnp.argmax(d_logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for t in range(S0, S0 + NEW - 1):
        d_logits, dense = model.decode_step(params, dense, tok,
                                            jnp.int32(t))
        p_logits, pool = model.decode_step_paged(
            params, pool, tok, jnp.full((B,), t, jnp.int32), tables)
        np.testing.assert_array_equal(np.asarray(d_logits),
                                      np.asarray(p_logits))
        tok = jnp.argmax(d_logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


def test_unsupported_arch_raises(model_and_params):
    cfg = get_arch("mamba2-2.7b-reduced")
    model = build_model(cfg)
    assert not model.supports_paged
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, max_slots=2, max_seq_len=16)


# ---------------------------------------------------------------------------
# Continuous batching: join/retire mid-loop
# ---------------------------------------------------------------------------

def test_engine_continuous_batching_matches_dense(model_and_params):
    """Requests joining and retiring mid-loop each reproduce their own
    dense-path greedy continuation exactly."""
    model, params = model_and_params
    engine = ServeEngine(model, params, max_slots=3, max_seq_len=32,
                         block_tokens=4)
    prompts = _prompts(5, lens=(5, 3, 7), vocab=model.cfg.vocab_size)
    news = [6, 4, 5, 6, 3]
    reqs = [engine.submit(p, n) for p, n in zip(prompts[:3], news[:3])]
    engine.step()                                  # 3 in flight
    engine.step()
    reqs += [engine.submit(p, n) for p, n in zip(prompts[3:], news[3:])]
    done = engine.run()
    assert len(done) == 5
    for p, n, r in zip(prompts, news, reqs):
        ref = generate(model, params, jnp.asarray([p], jnp.int32), n)
        assert r.generated == [int(t) for t in np.asarray(ref[0, len(p):])]
    # every block returned to the pool after retirement
    assert engine.cache.allocator.free_blocks == engine.cache.num_blocks - 1


def test_scheduler_join_retire_slot_reuse():
    """Pure-policy scheduler: a retired request's slot is reusable in the
    same step, and admission respects the cache gate."""
    reserved, released = [], []
    sched = Scheduler(max_slots=2, can_cover=lambda t: t <= 8,
                      reserve=lambda s, t: reserved.append((s, t)),
                      release=lambda s: released.append(s),
                      clock=lambda: 0.0)
    a = sched.submit([1, 2], max_new_tokens=2)
    b = sched.submit([3], max_new_tokens=3)
    big = sched.submit([1] * 7, max_new_tokens=9)  # budget 16 > gate
    assert sched.admit() == [a, b]
    assert reserved == [(0, 4), (1, 4)]
    sched.mark_decoding(a, 7)
    sched.append_token(a, 8)                       # a finished (2 tokens)
    assert a.finished
    assert sched.retire_finished() == [a]
    assert released == [0]
    assert sched.admit() == []                     # big can't cover
    assert sched.queued == 1 and big.state == "queued"
    assert sched.slot_of(0) is None                # slot 0 free again
    c = sched.submit([5], max_new_tokens=1)        # FIFO: big still blocks...
    assert sched.admit() == []                     # ...the queue head
    assert c.state == "queued"


def test_request_positions():
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    r.generated.append(9)                          # from prefill
    assert r.decode_pos == 3                       # writes position 3 next
    r.generated.append(9)
    assert r.decode_pos == 4
    assert r.total_budget == 7


# ---------------------------------------------------------------------------
# Replicated Byzantine-robust decode
# ---------------------------------------------------------------------------

def test_robust_decode_recovers_clean_output(model_and_params):
    """One garbage-parameter replica out of k=3: phocas and trmean decode
    the clean model's greedy output exactly; plain mean diverges."""
    model, params = model_and_params
    prompt = _prompts(1, lens=(5,), vocab=model.cfg.vocab_size)[0]
    NEW = 8
    clean = generate(model, params, jnp.asarray([prompt], jnp.int32), NEW)
    clean = [int(t) for t in np.asarray(clean[0, len(prompt):])]

    replicas = corrupt_replica(make_replicas(params, 3), 2,
                               jax.random.PRNGKey(9))
    outputs = {}
    for rule in ("phocas", "trmean", "mean"):
        dec = RobustDecoder(rule=rule, k=3, b=1 if rule != "mean" else 0)
        engine = ServeEngine(model, replicas, max_slots=2, max_seq_len=16,
                             block_tokens=4, decoder=dec)
        req = engine.submit(prompt, NEW)
        engine.run()
        outputs[rule] = req.generated
    assert outputs["phocas"] == clean
    assert outputs["trmean"] == clean
    assert outputs["mean"] != clean


def test_reputation_ejects_corrupted_replica(model_and_params):
    """A persistently-corrupted replica's EMA reputation decays below the
    ejection threshold; honest replicas stay active.  Mean emits only
    uniform zero scores, so it never ejects."""
    model, params = model_and_params
    prompt = _prompts(1, lens=(4,), vocab=model.cfg.vocab_size)[0]
    replicas = corrupt_replica(make_replicas(params, 3), 1,
                               jax.random.PRNGKey(3))

    dec = RobustDecoder(rule="phocas", k=3)
    engine = ServeEngine(model, replicas, max_slots=1, max_seq_len=32,
                         block_tokens=4, decoder=dec)
    engine.submit(prompt, 20)                      # enough steps to decay
    engine.run()
    assert dec.ejected_replicas() == [1]
    rep = np.asarray(dec.rep_state["reputation"])
    assert rep[1] < 0.5 < min(rep[0], rep[2])

    dec_mean = RobustDecoder(rule="mean", k=3, b=0)
    engine = ServeEngine(model, replicas, max_slots=1, max_seq_len=32,
                         block_tokens=4, decoder=dec_mean)
    engine.submit(prompt, 20)
    engine.run()
    assert dec_mean.ejected_replicas() == []


def test_robust_decoder_validation():
    with pytest.raises(ValueError):
        RobustDecoder(k=1)
    with pytest.raises(ValueError):
        RobustDecoder(k=3, b=2)                    # b > (k+1)//2-1


def test_engine_rejects_mismatched_replicas(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError):
        ServeEngine(model, params,                 # not a replica tuple
                    max_slots=2, max_seq_len=16,
                    decoder=RobustDecoder(k=3))


def test_replica_telemetry_stream(model_and_params, tmp_path):
    from repro.defense.telemetry import TelemetryWriter, read_jsonl
    model, params = model_and_params
    path = str(tmp_path / "tel.jsonl")
    replicas = corrupt_replica(make_replicas(params, 3), 0,
                               jax.random.PRNGKey(5))
    with TelemetryWriter(path) as tel:
        engine = ServeEngine(model, replicas, max_slots=1, max_seq_len=16,
                             block_tokens=4,
                             decoder=RobustDecoder(rule="trmean", k=3),
                             telemetry=tel)
        engine.submit([1, 2, 3], 6)
        engine.run()
    records = read_jsonl(path)
    kinds = {r["kind"] for r in records}
    assert {"robust_decode", "serve"} <= kinds
    scored = [r for r in records if r["kind"] == "robust_decode"]
    assert scored and len(scored[0]["scores"]) == 3
    assert scored[-1]["scores"][0] > max(scored[-1]["scores"][1:])
