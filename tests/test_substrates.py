"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
serving, HLO analyzer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import ClassificationData, TokenStream, make_worker_batches
from repro.optim import (OptConfig, apply_updates, cosine_decay, constant,
                         init_opt_state, warmup_cosine)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.02),
                                     ("adam", 0.1), ("adamw", 0.1)])
def test_optimizer_converges_quadratic(name, lr):
    params = {"x": jnp.array([5.0, -3.0])}
    cfg = OptConfig(name=name, lr=lr, weight_decay=0.0)
    state = init_opt_state(cfg, params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, state = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 1e-2
    assert int(state["step"]) == 300


def test_grad_clip():
    params = {"x": jnp.zeros(3)}
    cfg = OptConfig(name="sgd", lr=1.0, grad_clip=1.0)
    state = init_opt_state(cfg, params)
    p2, _ = apply_updates(cfg, params, {"x": jnp.full((3,), 100.0)}, state)
    assert abs(float(jnp.linalg.norm(p2["x"])) - 1.0) < 1e-5


def test_schedules():
    assert float(constant(0.1)(jnp.int32(5))) == pytest.approx(0.1)
    cd = cosine_decay(1.0, 100)
    assert float(cd(jnp.int32(0))) == pytest.approx(1.0)
    assert float(cd(jnp.int32(100))) == pytest.approx(0.1)
    wc = warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.int32(5))) == pytest.approx(0.5)
    assert float(wc(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)


def test_bf16_params_updated_in_f32():
    params = {"x": jnp.ones(4, jnp.bfloat16)}
    cfg = OptConfig(name="sgd", lr=0.01)
    state = init_opt_state(cfg, params)
    p2, _ = apply_updates(cfg, params, {"x": jnp.ones(4, jnp.bfloat16)}, state)
    assert p2["x"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_tokenstream_deterministic_and_learnable():
    ds = TokenStream(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(6)["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # bigram structure: empirical next-token entropy << uniform
    toks = np.asarray(ds.batch(0)["tokens"]).ravel()
    assert len(np.unique(toks)) > 10


def test_classification_data_separable():
    data = ClassificationData(num_classes=10, dim=64, noise=0.5, seed=0)
    batch = data.batch(0, 512)
    # nearest-mean classifier should do well -> task is learnable
    d = np.linalg.norm(np.asarray(batch["x"])[:, None]
                       - np.asarray(data.means)[None], axis=-1)
    acc = (d.argmin(1) == np.asarray(batch["y"])).mean()
    assert acc > 0.9, acc


def test_make_worker_batches():
    batch = {"x": jnp.arange(24).reshape(12, 2)}
    wb = make_worker_batches(batch, 4)
    assert wb["x"].shape == (4, 3, 2)
    with pytest.raises(AssertionError):
        make_worker_batches({"x": jnp.zeros((10, 2))}, 4)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_with_bf16():
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
            "b": [jnp.float32(3.5), jnp.int32(7)],
            "step": jnp.zeros((), jnp.int32)}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_checkpoint(path, tree, step=42)
        restored, step = load_checkpoint(path, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def test_generate_greedy_consistency():
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import generate
    cfg = get_arch("gemma2-2b-reduced")
    model = build_model(cfg)
    params = model.init(KEY)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    out = generate(model, params, prompts, max_new_tokens=6)
    assert out.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(out[:, :4]),
                                  np.asarray(prompts))
    # greedy decode must equal argmax of the parallel forward at each step
    full, _ = model.forward(params, {"tokens": out, "labels": out})
    preds = np.asarray(jnp.argmax(full, -1))
    np.testing.assert_array_equal(preds[:, 3:-1], np.asarray(out[:, 4:]))


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    t = analyze_hlo(hlo)
    assert t["dot_flops"] == 7 * 2 * 64**3
    assert t["loops"] and t["loops"][0]["trips"] == 7


def test_hlo_analyzer_collectives():
    from repro.launch.hlo_analysis import analyze_hlo
    # single-device psum lowers without collectives; just assert structure
    hlo = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile().as_text()
    t = analyze_hlo(hlo)
    assert t["collective_total_bytes"] == 0
