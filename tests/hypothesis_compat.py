"""Optional-hypothesis shim: property tests skip on minimal installs.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from hypothesis_compat import given, settings, st

When ``hypothesis`` is importable these are the real objects; otherwise
``@given``/``@settings`` become skip decorators and ``st`` an inert
stand-in so strategy-builder calls at module import still evaluate.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # minimal install
    HAVE_HYPOTHESIS = False

    def _skip_property_test(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = settings = _skip_property_test

    class _NoStrategies:
        """Strategy-builder calls (``st.integers(...)``, ``@st.composite``)
        must still evaluate at module import; they return inert
        placeholders."""

        def composite(self, fn):
            return lambda *a, **k: None

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()
