# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device.  Multi-device tests spawn subprocesses
# with their own XLA_FLAGS (see tests/test_distributed.py).
import jax

jax.config.update("jax_enable_x64", False)
