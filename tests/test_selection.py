"""Shared-selection hot path (core/selection.py, DESIGN.md §8).

New-vs-old equivalence for every coordinate-wise rule across
with_scores/active combinations and both collective layouts: the oracles
below reimplement the pre-fusion ``jnp.sort`` + double-``argsort`` paths
verbatim, so these tests pin the selection rewrite to the seed semantics
(including the gated-aggregate / raw-score defense contract).  Plus unit
coverage of the selection primitives themselves and the geomedian
norm-clip regression (ROADMAP item d, BENCH_detection.json).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RobustConfig, aggregate_matrix, gate_matrix,
                        registry, selection)
from repro.core.registry import (AggregatorRule, drop_frequency_scores)

KEY = jax.random.PRNGKey(11)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M, D, B = 9, 257, 2


# ---------------------------------------------------------------------------
# Pre-fusion oracles (the seed implementations, verbatim semantics)
# ---------------------------------------------------------------------------

def old_median(u):
    return jnp.median(u, axis=0)


def old_trmean(u, b):
    m = u.shape[0]
    s = jnp.sort(u, axis=0)
    return jnp.mean(s[b:m - b], axis=0) if b else jnp.mean(s, axis=0)


def old_phocas(u, b):
    m = u.shape[0]
    if b == 0:
        return jnp.mean(u, axis=0)
    center = old_trmean(u, b)
    dist = jnp.abs(u - center[None])
    ranks = jnp.argsort(jnp.argsort(dist, axis=0), axis=0)
    keep = (ranks < (m - b)).astype(u.dtype)
    return jnp.sum(u * keep, axis=0) / (m - b)


def old_mediam(u, b):
    m = u.shape[0]
    if b == 0:
        return jnp.mean(u, axis=0)
    center = jnp.median(u, axis=0)
    dist = jnp.abs(u - center[None])
    ranks = jnp.argsort(jnp.argsort(dist, axis=0), axis=0)
    dropped = ranks >= (m - b)
    return jnp.sum(u * (~dropped).astype(u.dtype), axis=0) / (m - b)


def old_mom(u, b):
    m = u.shape[0]
    g = min(2 * b + 1, m)
    if g <= 1:
        return jnp.mean(u, axis=0)
    gid = jnp.arange(m) % g
    onehot = (gid[None, :] == jnp.arange(g)[:, None]).astype(u.dtype)
    means = jnp.tensordot(onehot, u, axes=(1, 0)) \
        / jnp.sum(onehot, axis=1)[:, None]
    return jnp.median(means, axis=0)


def old_drop_counts(u, b, rule):
    """Seed double-argsort selection masks -> per-worker drop counts."""
    m = u.shape[0]
    if rule == "trmean":
        ranks = jnp.argsort(jnp.argsort(u, axis=0), axis=0)
        dropped = (ranks < b) | (ranks >= m - b)
    else:
        center = old_trmean(u, b) if rule == "phocas" \
            else jnp.median(u, axis=0)
        dist = jnp.abs(u - center[None])
        ranks = jnp.argsort(jnp.argsort(dist, axis=0), axis=0)
        dropped = ranks >= (m - b)
    return jnp.sum(dropped, axis=1).astype(jnp.float32)


def old_gate(u, active):
    med = jnp.median(u, axis=0)
    return jnp.where(active[:, None] > 0, u, med[None])


OLD_AGG = {"median": lambda u, b: old_median(u),
           "trmean": old_trmean, "phocas": old_phocas,
           "mediam": old_mediam, "mom": old_mom,
           "mean": lambda u, b: jnp.mean(u, axis=0)}
BASELINE = {"trmean": lambda b, m: 2.0 * b / m,
            "phocas": lambda b, m: b / m,
            "mediam": lambda b, m: b / m}


def _u(seed=0, m=M, d=D):
    # continuous data: tie configurations (measure-zero, where old/new
    # legitimately differ in which equal-distance value they drop) excluded
    return 3.0 * jax.random.normal(jax.random.fold_in(KEY, seed), (m, d))


# ---------------------------------------------------------------------------
# New-vs-old: plain aggregates, every coordinate-wise rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", registry.coordinate_wise_rules())
def test_new_vs_old_plain_aggregate(rule):
    assert rule in OLD_AGG, f"add a pre-fusion oracle for new rule {rule!r}"
    u = _u(1)
    got = aggregate_matrix(u, RobustConfig(rule=rule, b=B, backend="xla"))
    ref = OLD_AGG[rule](u, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("rule", ("trmean", "phocas", "mediam"))
@pytest.mark.parametrize("gated", (False, True))
def test_new_vs_old_with_scores_and_gate(rule, gated):
    """with_scores x active: aggregate AND scores match the seed two-pass
    path (scores observe RAW submissions; aggregate uses the gated
    matrix)."""
    u = _u(2)
    active = jnp.ones((M,)).at[4].set(0.0).at[7].set(0.0) if gated else None
    cfg = RobustConfig(rule=rule, b=B, backend="xla")
    got_agg, got_scores = aggregate_matrix(u, cfg, active=active,
                                           with_scores=True)
    ref_scores = drop_frequency_scores(
        old_drop_counts(u, B, rule), jnp.float32(D), BASELINE[rule](B, M))
    ref_agg = OLD_AGG[rule](old_gate(u, active), B) if gated \
        else OLD_AGG[rule](u, B)
    np.testing.assert_allclose(np.asarray(got_scores),
                               np.asarray(ref_scores), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_agg), np.asarray(ref_agg),
                               atol=1e-4)


@pytest.mark.parametrize("rule", ("trmean", "phocas", "mediam"))
def test_fused_hook_matches_composed_default(rule):
    """The trim-family override of reduce_sharded_gated_with_scores is
    drop-in for the registry's composed default."""
    u = _u(3)
    active = jnp.ones((M,)).at[0].set(0.0)
    r = registry.make_rule(rule, registry.RuleParams(b=B, backend="xla"))
    got_agg, got_sc = r.reduce_gated_with_scores(u, active)
    ref_agg, ref_sc = AggregatorRule.reduce_sharded_gated_with_scores(
        r, u, active, ())
    np.testing.assert_allclose(np.asarray(got_sc), np.asarray(ref_sc),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_agg), np.asarray(ref_agg),
                               atol=1e-4)


def test_gate_matrix_concrete_all_ones_is_free():
    u = _u(4)
    assert gate_matrix(u, jnp.ones((M,))) is u        # short-circuit
    active = jnp.ones((M,)).at[2].set(0.0)
    np.testing.assert_allclose(np.asarray(gate_matrix(u, active)),
                               np.asarray(old_gate(u, active)), atol=1e-6)


# ---------------------------------------------------------------------------
# Selection primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 20, 33, 64])
def test_sorted_rows_matches_jnp_sort(m):
    u = _u(5, m=m, d=101)
    got = jnp.stack(selection.sorted_rows(selection.worker_rows(u)))
    np.testing.assert_allclose(np.asarray(got),
                               np.sort(np.asarray(u), axis=0), atol=0)


@pytest.mark.parametrize("m", [2, 7, 16, 40])
def test_stable_ranks_match_double_argsort_with_duplicates(m):
    key = jax.random.fold_in(KEY, m)
    # heavy duplicates: integer-quantized values
    u = jnp.floor(4 * jax.random.normal(key, (m, 57)))
    ref = jnp.argsort(jnp.argsort(u, axis=0), axis=0)
    got = jnp.stack(selection.stable_ranks(selection.worker_rows(u)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_sorted_rows_large_m_fallback(monkeypatch):
    monkeypatch.setattr(selection, "_NETWORK_MAX_M", 4)
    monkeypatch.setattr(selection, "_PAIRWISE_MAX_M", 4)
    u = _u(6, m=9, d=33)
    got = jnp.stack(selection.sorted_rows(selection.worker_rows(u)))
    np.testing.assert_allclose(np.asarray(got),
                               np.sort(np.asarray(u), axis=0), atol=0)
    ref = jnp.argsort(jnp.argsort(u, axis=0), axis=0)
    got_r = jnp.stack(selection.stable_ranks(selection.worker_rows(u)))
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(ref))


def test_nan_submissions_are_trimmed_not_propagated():
    """A NaN row (the cheapest Byzantine payload) must be selected against
    like jnp.sort's NaN-last placement, not poison every coordinate
    through the network's min/max compare-exchanges."""
    u = jnp.array([[0.0], [1.0], [2.0], [3.0], [jnp.nan]])
    np.testing.assert_allclose(
        np.asarray(selection.trim_family(u, 1, "trmean")[0]), [2.0])
    for kind in ("phocas", "mediam"):
        agg, counts, _ = selection.trim_family(u, 1, kind, with_scores=True)
        assert np.isfinite(np.asarray(agg)).all(), kind
        assert float(counts[4]) == 1.0, kind       # NaN worker is blamed


def test_b0_fused_gate_still_ejects():
    """b=0 degenerates to the mean but the reputation gate must still
    keep an ejected row out of the aggregate (review regression)."""
    u = jnp.array([[0.0], [1.0], [2.0], [1e6]])
    active = jnp.ones((4,)).at[3].set(0.0)
    for rule in ("trmean", "phocas", "mediam"):
        r = registry.make_rule(rule, registry.RuleParams(b=0, backend="xla"))
        got, _ = r.reduce_gated_with_scores(u, active)
        ref, _ = AggregatorRule.reduce_sharded_gated_with_scores(
            r, u, active, ())
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, err_msg=rule)
        assert float(got[0]) < 10.0, rule          # 1e6 row stayed out


def test_pallas_scores_large_m_falls_back_to_xla():
    """m above the counts kernels' 128-lane pack must fall back to the
    XLA selection path instead of crashing (review regression)."""
    u = _u(12, m=130, d=64)
    rp = registry.make_rule("trmean",
                            registry.RuleParams(b=2, backend="pallas"))
    rx = registry.make_rule("trmean",
                            registry.RuleParams(b=2, backend="xla"))
    pa, ps = rp.reduce_with_scores(u)
    xa, xs = rx.reduce_with_scores(u)
    np.testing.assert_allclose(np.asarray(ps), np.asarray(xs), atol=1e-6)
    np.testing.assert_allclose(np.asarray(pa), np.asarray(xa), atol=1e-4)


def test_nearest_window_no_prefix_cancellation():
    """The window sum must survive a 1e19 adversarial row (the bitflip
    regression that rules out a prefix-sum implementation)."""
    u = jnp.concatenate([1.0 + 0.01 * _u(7, m=10, d=16),
                         jnp.full((2, 16), -1.5e19)])
    agg = selection.trim_family(u, 2, "mediam")[0]
    assert np.abs(np.asarray(agg) - 1.0).max() < 0.5


# ---------------------------------------------------------------------------
# Score-emitting kernels: pallas == xla in interpret mode, both variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,b", [(8, 2), (8, 3), (20, 2), (20, 9), (5, 2)])
def test_trmean_counts_kernel_matches_xla(m, b):
    from repro.core.aggregators import trmean_stats
    from repro.kernels.trmean.ops import trmean_with_counts
    u = _u(8, m=m, d=3001)
    ka, kc = trmean_with_counts(u, b)
    xa, xc, _ = trmean_stats(u, b)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(xa), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(xc), atol=0)


@pytest.mark.parametrize("m,b", [(8, 2), (8, 3), (20, 2), (20, 9), (5, 2)])
def test_phocas_counts_kernel_matches_xla(m, b):
    from repro.core.aggregators import phocas_stats
    from repro.kernels.phocas.ops import phocas_with_counts
    u = _u(9, m=m, d=3001)
    ka, kc = phocas_with_counts(u, b)
    xa, xc, _ = phocas_stats(u, b)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(xa), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(xc), atol=0)


def test_kernel_network_variant_heuristic():
    from repro.kernels.trmean.kernel import use_network
    assert not use_network(8, 2 * 2)      # trmean m=8 b=2: extraction
    assert use_network(8, 3 * 3)          # phocas m=8 b=3: network
    assert use_network(20, 3 * 9)         # big-b phocas: network


def test_pallas_backend_scores_through_rule():
    """emits_scores no longer forces the XLA fallback: the pallas backend
    serves reduce_with_scores through the counts kernels."""
    u = _u(10, m=8, d=501)
    for rule in ("trmean", "phocas"):
        rp = registry.make_rule(rule,
                                registry.RuleParams(b=2, backend="pallas"))
        rx = registry.make_rule(rule,
                                registry.RuleParams(b=2, backend="xla"))
        pa, ps = rp.reduce_with_scores(u)
        xa, xs = rx.reduce_with_scores(u)
        np.testing.assert_allclose(np.asarray(pa), np.asarray(xa),
                                   atol=1e-4, err_msg=rule)
        np.testing.assert_allclose(np.asarray(ps), np.asarray(xs),
                                   atol=1e-6, err_msg=rule)


# ---------------------------------------------------------------------------
# Geomedian norm-clip regression (ROADMAP item d / BENCH_detection.json)
# ---------------------------------------------------------------------------

def test_geomedian_scores_localize_under_omniscient_blowup():
    """Seed behavior: omniscient's 1e20 rows kept the 8-iter Weiszfeld
    fixed point from localizing, destroying the rule's suspicion scores.
    With the pre-iteration norm clip the Byzantine rows separate
    cleanly."""
    q = 3
    u = 1.0 + 0.1 * jax.random.normal(KEY, (12, 64))
    u = u.at[:q].set(-1e20)               # omniscient_scale rows
    z, scores = aggregate_matrix(u, RobustConfig(rule="geomedian"),
                                 with_scores=True)
    scores = np.asarray(scores)
    assert scores[:q].min() > scores[q:].max() + 0.2, scores
    assert np.abs(np.asarray(z) - 1.0).max() < 0.5    # fixed point localized


def test_geomedian_clip_leaves_clean_runs_unchanged():
    u = 1.0 + 0.1 * jax.random.normal(KEY, (10, 64))
    from repro.core.aggregators import clip_rows_to_norm_quantile
    np.testing.assert_array_equal(
        np.asarray(clip_rows_to_norm_quantile(u, ())), np.asarray(u))


# ---------------------------------------------------------------------------
# Both collective layouts x active x with_scores (subprocess, 8 devices)
# ---------------------------------------------------------------------------

DIST_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import RobustConfig, robust_aggregate_dist, aggregate_matrix

mesh = jax.make_mesh((4, 2), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
key = jax.random.PRNGKey(5)
base = 1.0 + 0.1*jax.random.normal(key, (4, 64))
base = base.at[0].set(30.0 * base[0])
grads = {'w': base[:, :60], 'b': base[:, 60:]}
from jax.flatten_util import ravel_pytree
mat = np.stack([ravel_pytree(jax.tree.map(lambda x: x[i], grads))[0]
                for i in range(4)])
active = jnp.ones((4,)).at[0].set(0.0)
results = {}
for rule in ('median', 'trmean', 'phocas', 'mediam', 'mom'):
    cfg_l = RobustConfig(rule=rule, b=1, q=1)
    for layout in ('replicated', 'sharded'):
        cfg = RobustConfig(rule=rule, b=1, q=1, layout=layout)
        for ws in (False, True):
            for act in (None, active):
                ref = aggregate_matrix(jnp.asarray(mat), cfg_l,
                                       active=act, with_scores=ws)
                ref_agg, ref_sc = ref if ws else (ref, None)
                @partial(jax.shard_map, mesh=mesh,
                         in_specs=(P('data'), P()),
                         out_specs=(P(), P()) if ws else P(),
                         check_vma=False)
                def f(g, a):
                    local = jax.tree.map(lambda x: x[0], g)
                    out = robust_aggregate_dist(
                        local, cfg, worker_axes=('data',),
                        model_axes=('model',), active=a, with_scores=ws)
                    if ws:
                        return ravel_pytree(out[0])[0], out[1]
                    return ravel_pytree(out)[0]
                out = f(grads, act if act is not None else jnp.ones((4,)))
                # active=None vs all-ones gate are equivalent semantics
                flat, sc = out if ws else (out, None)
                ok = bool(np.allclose(np.asarray(flat), np.asarray(ref_agg),
                                      atol=1e-4))
                if ws:
                    ok = ok and bool(np.allclose(np.asarray(sc),
                                                 np.asarray(ref_sc),
                                                 atol=1e-4))
                results[f'{rule}/{layout}/ws{int(ws)}/'
                        f'act{int(act is not None)}'] = ok
print(json.dumps(results))
"""


@pytest.mark.slow
def test_layouts_with_scores_and_gate_match_local():
    """Every coordinate-wise rule x layout x with_scores x active combo
    reproduces the local path through shard_map (the §6/§7 psum and gate
    contracts survive the shared-selection rewrite)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", DIST_EQUIV],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(results) == 5 * 2 * 2 * 2
    bad = [k for k, v in results.items() if not v]
    assert not bad, bad


def test_stable_ranks_fallback_warns_once_above_cliff():
    """Above _PAIRWISE_MAX_M stable_ranks routes through the double-argsort
    fallback — same bits, but it must say so (once per process) instead of
    silently re-paying the two XLA sorts (ROADMAP selection follow-up c)."""
    import warnings
    from repro.core import selection
    m = selection._PAIRWISE_MAX_M + 1
    keys = [jnp.arange(4, dtype=jnp.float32) * i for i in range(m)]
    orig = selection._RANK_FALLBACK_WARNED
    selection._RANK_FALLBACK_WARNED = False
    try:
        with pytest.warns(RuntimeWarning, match="double-argsort"):
            got = selection.stable_ranks(keys)
        # exact fallback semantics: argsort(argsort(...))
        ref = jnp.argsort(jnp.argsort(jnp.stack(keys), axis=0), axis=0)
        np.testing.assert_array_equal(np.asarray(jnp.stack(got)),
                                      np.asarray(ref))
        # one-time: a second call stays silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            selection.stable_ranks(keys)
    finally:
        selection._RANK_FALLBACK_WARNED = orig
