"""Assignment conformance: every architecture config matches the assigned
numbers exactly, and the input shapes match the assigned grid."""
import pytest

from repro.configs import INPUT_SHAPES, get_arch, list_archs

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment block
ASSIGNED = {
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "granite-8b": (36, 4096, 32, 8, 14336, 49152),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
}


def test_all_ten_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_dimensions(arch):
    cfg = get_arch(arch)
    L, d, H, kv, ff, V = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.citation


def test_family_specifics():
    assert get_arch("mamba2-2.7b").ssm_state == 128
    k = get_arch("kimi-k2-1t-a32b")
    assert (k.num_experts, k.num_experts_per_tok) == (384, 8)
    ds = get_arch("deepseek-v2-lite-16b")
    assert ds.use_mla and ds.kv_lora_rank == 512
    assert (ds.num_experts, ds.num_experts_per_tok,
            ds.num_shared_experts) == (64, 6, 2)
    hy = get_arch("hymba-1.5b")
    assert hy.hybrid and hy.ssm_state == 16
    g3 = get_arch("gemma3-27b")
    assert g3.window_pattern == (1024,) * 5 + (None,)      # 5:1 local:global
    g2 = get_arch("gemma2-2b")
    assert g2.attn_logit_softcap == 50.0 and g2.final_logit_softcap == 30.0
    w = get_arch("whisper-large-v3")
    assert w.encoder_layers == 32 and w.encoder_seq_len == 1500
    iv = get_arch("internvl2-26b")
    assert iv.num_patches == 256 and iv.vit_dim == 3200


def test_input_shapes_grid():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["decode_32k"].is_decode
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_variants_are_small(arch):
    r = get_arch(arch + "-reduced")
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.family == get_arch(arch).family


def test_long_context_applicability():
    """DESIGN.md §4 skips: pure full-attention archs don't support
    long_500k; SSM/hybrid/windowed ones do."""
    supports = {a: get_arch(a).supports_long_context for a in list_archs()}
    assert supports["mamba2-2.7b"] and supports["hymba-1.5b"]
    assert supports["gemma3-27b"] and supports["gemma2-2b"]
    assert supports["starcoder2-7b"]
    for a in ("granite-8b", "kimi-k2-1t-a32b", "internvl2-26b",
              "whisper-large-v3", "deepseek-v2-lite-16b"):
        assert not supports[a], a


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_arch("gpt5")
