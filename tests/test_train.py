"""End-to-end Byzantine-resilient training: the paper's qualitative claims
as executable tests (MLP on the Gaussian-mixture MNIST stand-in)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AttackConfig, RobustConfig
from repro.data import ClassificationData, make_worker_batches
from repro.models.mlp import build_mlp_model, mlp_accuracy
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step

M = 20                       # paper: 20 workers
DIM, CLASSES = 64, 10


def run_training(rule, attack, *, b=6, q=6, steps=60, lr=0.1,
                 backend="xla"):
    data = ClassificationData(num_classes=CLASSES, dim=DIM, noise=0.8, seed=1)
    model = build_mlp_model(dims=(DIM, 64, CLASSES))
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(name="sgd", lr=lr)
    rob = RobustConfig(rule=rule, b=b, q=q, backend=backend,
                       attack=attack)
    step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                           num_workers=M, mesh=None, donate=False)
    opt_state = init_opt_state(opt_cfg, params)
    key = jax.random.PRNGKey(42)
    for i in range(steps):
        batch = make_worker_batches(data.batch(i, 20 * M), M)
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jax.random.fold_in(key, i))
    test = data.test_set(1024)
    return float(mlp_accuracy(params, test)), metrics


CLEAN = AttackConfig(name="none")
GAUSS = AttackConfig(name="gaussian", num_byzantine=6)
OMNI = AttackConfig(name="omniscient", num_byzantine=6)
BITFLIP = AttackConfig(name="bitflip", num_byzantine=1)
GAMBLER = AttackConfig(name="gambler", gambler_prob=0.02)


def test_clean_baseline_learns():
    acc, _ = run_training("mean", CLEAN)
    assert acc > 0.75, acc


def test_mean_fails_under_gaussian():
    """Paper §5.1.1: averaging is not Byzantine resilient — with zero-mean
    Gaussian corruption on a separable task that manifests as heavily
    degraded convergence, while Phocas performs as if there were no
    failures at all.  Compare at 15 steps, where the clean baseline (and
    Phocas) have already converged."""
    acc_mean, _ = run_training("mean", GAUSS, steps=15)
    acc_phocas, _ = run_training("phocas", GAUSS, steps=15)
    acc_clean, _ = run_training("mean", CLEAN, steps=15)
    assert acc_clean > 0.95, acc_clean
    assert acc_phocas > 0.95, acc_phocas          # ≈ no-failure
    assert acc_phocas - acc_mean > 0.2, (acc_mean, acc_phocas)


def test_omniscient_phocas_survives_trmean_degrades():
    """Paper §5.1.2 ordering: Phocas ≈ no-failure; Mean diverges."""
    acc_mean, m_mean = run_training("mean", OMNI)
    acc_phocas, _ = run_training("phocas", OMNI)
    assert acc_phocas > 0.7, acc_phocas
    assert acc_mean < 0.3 or not np.isfinite(m_mean["loss"])


def test_bitflip_dimensional_resilience():
    """Paper §5.1.3: only Trmean/Phocas survive the dimensional attack;
    Krum gets stuck."""
    acc_trmean, _ = run_training("trmean", BITFLIP, b=8, q=8)
    acc_phocas, _ = run_training("phocas", BITFLIP, b=8, q=8)
    acc_krum, _ = run_training("krum", BITFLIP, b=8, q=8)
    assert acc_trmean > 0.7, acc_trmean
    assert acc_phocas > 0.7, acc_phocas
    assert acc_krum < acc_phocas - 0.15, (acc_krum, acc_phocas)


def test_gambler_trmean_survives():
    """Paper §5.1.4: dimensional rules survive the multi-server attack."""
    acc, _ = run_training("trmean", GAMBLER, b=8, q=8)
    assert acc > 0.7, acc


def test_kernel_backed_training_matches_ref():
    """backend='pallas' (interpret mode on CPU) trains identically."""
    a1, _ = run_training("phocas", GAUSS, steps=25)
    a2, _ = run_training("phocas", GAUSS, steps=25, backend="pallas")
    assert abs(a1 - a2) < 0.05, (a1, a2)


@pytest.mark.parametrize("opt", ["momentum", "adam"])
def test_robust_aggregation_composes_with_optimizers(opt):
    """Beyond-paper: Δ-resilient aggregate feeds any optimizer."""
    data = ClassificationData(num_classes=CLASSES, dim=DIM, noise=0.8, seed=1)
    model = build_mlp_model(dims=(DIM, 64, CLASSES))
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(name=opt, lr=0.05 if opt == "momentum" else 0.005)
    rob = RobustConfig(rule="phocas", b=6, attack=GAUSS)
    step = make_train_step(model, robust_cfg=rob, opt_cfg=opt_cfg,
                           num_workers=M, mesh=None, donate=False)
    opt_state = init_opt_state(opt_cfg, params)
    key = jax.random.PRNGKey(9)
    for i in range(60):
        batch = make_worker_batches(data.batch(i, 20 * M), M)
        params, opt_state, _ = step(params, opt_state, batch,
                                    jax.random.fold_in(key, i))
    acc = float(mlp_accuracy(params, data.test_set(1024)))
    assert acc > 0.7, acc
