"""repro.obs: metrics registry semantics, exposition golden file, span
nesting under the tracer, the Recorder bus (sinks + gauge mirroring +
lifecycle), jsonify non-finite round-trips, recorder-through-
``run_experiment`` integration for all four topologies, and the reporter
CLI on a checked-in fixture JSONL."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import AttackConfig, RobustConfig
from repro.defense import DefenseConfig
from repro.defense.telemetry import (INF_CLAMP, TelemetryWriter, jsonify,
                                     read_jsonl)
from repro.experiment import (DataSpec, ModelSpec, ScenarioSpec,
                              run_experiment)
from repro.obs import (DEFAULT_MS_BUCKETS, Counter, Gauge, Histogram,
                       MetricsRegistry, ObsConfig, Recorder, SCHEMA,
                       as_recorder, check_kind, make_recorder,
                       parse_exposition, render_prometheus,
                       validate_record)
from repro.obs.trace import NULL_SPAN, current_path

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "obs")


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------

def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_last_write_wins():
    g = Gauge()
    g.set(3)
    g.set(-1.5)
    assert g.value == -1.5


def test_histogram_bucket_edges():
    h = Histogram(bounds=(1.0, 10.0, 100.0))
    # le is INCLUSIVE: a value exactly on an edge lands in that bucket.
    h.observe(1.0)
    h.observe(0.1)
    h.observe(10.0)
    h.observe(10.000001)
    h.observe(1e9)              # overflow -> +Inf slot
    h.observe(-5.0)             # below the first bound -> first bucket
    assert h.counts == [3, 1, 1, 1]
    assert h.cumulative() == [3, 4, 5, 6]
    assert h.count == 6
    assert h.sum == pytest.approx(1.0 + 0.1 + 10.0 + 10.000001 + 1e9 - 5.0)


def test_histogram_quantiles():
    h = Histogram(bounds=(1.0, 10.0, 100.0))
    for v in [0.5] * 98 + [50.0, 1e6]:
        h.observe(v)
    assert h.quantile(0.5) == 1.0
    assert h.quantile(0.99) == 100.0
    # +Inf bucket reports the last finite bound
    assert h.quantile(1.0) == 100.0
    assert Histogram().quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram(bounds=())
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(bounds=(10.0, 1.0))


def test_registry_label_children_and_type_conflict():
    reg = MetricsRegistry()
    a = reg.counter("hits", rule="phocas")
    b = reg.counter("hits", rule="mean")
    assert a is not b
    assert reg.counter("hits", rule="phocas") is a      # same child back
    assert reg.get("hits", rule="mean") is b
    assert reg.get("hits", rule="nope") is None
    assert reg.get("nope") is None
    with pytest.raises(ValueError):
        reg.gauge("hits")                               # type conflict


# ---------------------------------------------------------------------------
# Exposition: golden file + parser round-trip
# ---------------------------------------------------------------------------

def _golden_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("ejections", stream="train").inc(2)
    reg.gauge("q_hat").set(1)
    reg.gauge("resilience_margin", rule="phocas").set(1.0)
    h = reg.histogram("agg_ms", buckets=(1.0, 10.0, 100.0), rule="phocas")
    for v in (0.5, 1.0, 7.5, 250.0):
        h.observe(v)
    return reg


def test_exposition_golden_file():
    with open(os.path.join(FIXTURES, "golden.prom")) as fh:
        golden = fh.read()
    assert render_prometheus(_golden_registry()) == golden


def test_exposition_parse_roundtrip():
    text = render_prometheus(_golden_registry())
    fams = parse_exposition(text)
    assert fams["repro_ejections"]["type"] == "counter"
    (_, labels, value), = fams["repro_ejections"]["samples"]
    assert labels == {"stream": "train"} and value == 2.0
    hist = fams["repro_agg_ms"]
    assert hist["type"] == "histogram"
    buckets = {s[1]["le"]: s[2] for s in hist["samples"]
               if s[0].endswith("_bucket")}
    # cumulative and le-inclusive: 0.5 and 1.0 both land in le="1"
    assert buckets == {"1": 2.0, "10": 3.0, "100": 3.0,
                       "+Inf": 4.0}
    count, = (s[2] for s in hist["samples"] if s[0].endswith("_count"))
    assert count == 4.0


def test_exposition_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("c", path='a"b\\c\nd').inc()
    text = render_prometheus(reg)
    fams = parse_exposition(text)
    (_, labels, _), = fams["repro_c"]["samples"]
    assert labels == {"path": 'a"b\\c\nd'}


def test_parse_exposition_rejects_malformed():
    with pytest.raises(ValueError):
        parse_exposition("this is not { exposition")


# ---------------------------------------------------------------------------
# Spans: nesting, stack restore, disabled-mode zero cost
# ---------------------------------------------------------------------------

def test_span_nesting_paths():
    rec = Recorder(registry=MetricsRegistry(), trace=True)
    with rec.span("outer"):
        assert current_path() == "outer"
        with rec.span("inner", rule="phocas"):
            assert current_path() == "outer/inner"
        assert current_path() == "outer"
    assert current_path() == ""
    fams = parse_exposition(rec.snapshot())
    names = {s[1].get("name") for s in fams["repro_span_ms"]["samples"]}
    assert names == {"outer", "outer/inner"}


def test_span_stack_restored_on_exception():
    rec = Recorder(registry=MetricsRegistry(), trace=True)
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("x")
    assert current_path() == ""
    # the failed span still recorded its wall time
    assert rec.registry.get("span_ms", name="boom").count == 1


def test_span_sync_returns_value():
    import jax.numpy as jnp
    rec = Recorder(registry=MetricsRegistry(), trace=True)
    with rec.span("s") as sp:
        x = sp.sync(jnp.ones((3,)))
    assert x.shape == (3,)
    assert NULL_SPAN.sync("passthrough") == "passthrough"


def test_disabled_recorder_spans_allocate_nothing():
    rec = Recorder()
    assert not rec.enabled
    # the no-op span is one shared singleton — nothing per call
    assert rec.span("a") is rec.span("b") is NULL_SPAN
    with rec.span("a"):
        pass
    # metrics-on but trace-off also stays on the null span
    rec2 = Recorder(registry=MetricsRegistry(), trace=False)
    assert rec2.span("a") is NULL_SPAN
    # every bus method is a no-op, not an error
    rec.count("x")
    rec.gauge("x", 1.0)
    rec.observe("x", 1.0)
    rec.emit("train", 0, loss=1.0)
    rec.close()


# ---------------------------------------------------------------------------
# The Recorder bus
# ---------------------------------------------------------------------------

def test_schema_check_kind():
    assert check_kind("train") == "train"
    with pytest.raises(ValueError):
        check_kind("trian")
    assert set(SCHEMA) >= {"train", "serve", "decode", "metric", "span"}


def test_validate_record():
    assert validate_record({"t": 0, "kind": "train", "step": 1}) == []
    bad = validate_record({"kind": "nope"})
    assert any("t" in p for p in bad) and any("nope" in p for p in bad)


def test_emit_rejects_unknown_kind(tmp_path):
    rec = make_recorder(str(tmp_path / "t.jsonl"))
    with pytest.raises(ValueError):
        rec.emit("not_a_kind", 0, x=1)  # repro: noqa[CONTRACT010] the test IS the typo'd-kind case
    rec.close()


def test_recorder_mirrors_scalars_to_gauges(tmp_path):
    path = str(tmp_path / "t.jsonl")
    rec = make_recorder(path, ObsConfig(trace=False))
    rec.emit("train", 3, loss=0.5, suspicion=[0.1, 0.9], q_hat=1)
    assert rec.registry.get("train_loss").value == 0.5
    assert rec.registry.get("train_q_hat").value == 1.0
    assert rec.registry.get("train_suspicion") is None   # non-scalar
    rec.close()
    recs = read_jsonl(path)
    assert recs[0]["kind"] == "train" and recs[0]["loss"] == 0.5
    # close() dumped the registry as "metric" records after the stream
    metric_names = {r["name"] for r in recs if r["kind"] == "metric"}
    assert {"train_loss", "train_q_hat"} <= metric_names


def test_recorder_close_idempotent_and_snapshot(tmp_path):
    snap = str(tmp_path / "m.prom")
    rec = make_recorder(None, ObsConfig(metrics_path=snap))
    rec.count("steps", 3)
    rec.close()
    rec.close()
    fams = parse_exposition(open(snap).read())
    assert fams["repro_steps"]["samples"][0][2] == 3.0


def test_as_recorder_adapts_writer(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with TelemetryWriter(path) as tel:
        rec = as_recorder(tel)
        rec.log("serve", 0, produced=2)
        assert as_recorder(rec) is rec
        rec.close()                      # not owned: must NOT close tel
        tel.log("serve", 1, produced=3)
    assert [r["step"] for r in read_jsonl(path)] == [0, 1]
    assert not as_recorder(None).enabled


# ---------------------------------------------------------------------------
# jsonify non-finite handling (satellite: NaN -> null, inf -> clamp)
# ---------------------------------------------------------------------------

def test_jsonify_non_finite_floats():
    assert jsonify(float("nan")) is None
    assert jsonify(float("inf")) == INF_CLAMP
    assert jsonify(float("-inf")) == -INF_CLAMP
    assert jsonify(np.float32("nan")) is None
    assert jsonify([1.0, float("nan"), float("inf")]) \
        == [1.0, None, INF_CLAMP]
    # the clamp survives strict JSON as a NUMBER
    assert json.loads(json.dumps(jsonify(float("inf")))) == INF_CLAMP


def test_telemetry_roundtrip_non_finite(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with TelemetryWriter(path) as tel:
        tel.log("train", 0, loss=float("nan"),
                grad_norm=float("inf"),
                suspicion=[0.5, float("-inf")])
    rec, = read_jsonl(path)
    assert rec["loss"] is None
    assert rec["grad_norm"] == INF_CLAMP
    assert rec["suspicion"] == [0.5, -INF_CLAMP]
    # strict JSON all the way down: the raw line parses with a strict
    # decoder that rejects NaN/Infinity literals
    with open(path) as fh:
        json.loads(fh.readline(), parse_constant=lambda c: 1 / 0)


# ---------------------------------------------------------------------------
# Recorder through run_experiment: all four topologies
# ---------------------------------------------------------------------------

def _train_spec(topology: str, tmp_path) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"obs-{topology}", topology=topology,
        topology_params=({"staleness": 2} if topology == "async_ps"
                         else {}),
        model=ModelSpec(kind="mlp"),
        data=DataSpec(kind="classification", dim=16, batch_per_worker=4),
        robust=RobustConfig(rule="phocas", b=2, q=2),
        attack=AttackConfig(name="gaussian", num_byzantine=2),
        defense=(DefenseConfig() if topology in ("sync_ps", "async_ps")
                 else None),
        num_workers=8, steps=3, log_every=1,
        telemetry_path=str(tmp_path / f"{topology}.jsonl"))


@pytest.mark.parametrize("topology", ["sync_ps", "async_ps", "streaming"])
def test_recorder_through_run_experiment_training(topology, tmp_path):
    spec = _train_spec(topology, tmp_path)
    snap = str(tmp_path / f"{topology}.prom")
    result = run_experiment(spec, obs=ObsConfig(metrics_path=snap))
    assert result.history

    records = read_jsonl(spec.telemetry_path)
    kinds = {r["kind"] for r in records}
    assert "span" in kinds                       # tracing was armed
    assert all(not validate_record(r) for r in records)

    fams = parse_exposition(open(snap).read())
    assert "repro_span_ms" in fams
    assert "repro_steps" in fams
    span_rules = {s[1].get("rule")
                  for s in fams["repro_span_ms"]["samples"]}
    assert "phocas" in span_rules                # per-rule latency series
    if topology in ("sync_ps", "async_ps"):      # defended paths
        assert "repro_q_hat" in fams
        assert "repro_resilience_margin" in fams


def test_recorder_through_run_experiment_serve(tmp_path):
    tel = str(tmp_path / "serve.jsonl")
    snap = str(tmp_path / "serve.prom")
    spec = ScenarioSpec(
        name="obs-serve", topology="serve",
        model=ModelSpec(kind="arch", arch="granite-8b-reduced"),
        data=DataSpec(kind="tokens"),
        robust=RobustConfig(rule="phocas", b=1),
        attack=AttackConfig(name="gaussian", num_byzantine=1),
        topology_params={"replicas": 3, "max_slots": 2, "max_seq_len": 16,
                         "num_requests": 2, "arrival_rate": 4.0,
                         "prompt_len": 4, "max_new_tokens": 4},
        num_workers=8, steps=200,
        telemetry_path=tel)
    result = run_experiment(spec, obs=ObsConfig(metrics_path=snap))
    assert result.final_metrics["tokens"] == 8.0

    records = read_jsonl(tel)
    kinds = {r["kind"] for r in records}
    assert {"serve", "robust_decode", "span"} <= kinds
    assert all(not validate_record(r) for r in records)

    fams = parse_exposition(open(snap).read())
    names = {s[1].get("name") for s in fams["repro_span_ms"]["samples"]}
    assert {"prefill", "decode"} <= names
    assert "repro_serve_admitted" in fams


def test_run_experiment_without_obs_stays_dark(tmp_path):
    """obs=None (the default): telemetry JSONL only, no span/metric
    records, exactly the pre-obs on-disk stream."""
    spec = _train_spec("sync_ps", tmp_path)
    run_experiment(spec)
    kinds = [r["kind"] for r in read_jsonl(spec.telemetry_path)]
    assert kinds == ["train"] * 3


# ---------------------------------------------------------------------------
# Reporter CLI
# ---------------------------------------------------------------------------

def test_reporter_cli_on_fixture(capsys):
    from repro.obs.report import main
    rc = main([os.path.join(FIXTURES, "run.jsonl")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "loss: first=2.31 last=1.2" in out
    # ejection timeline reconstructed from active-mask transitions
    assert "worker 2 ejected (train)" in out
    assert "worker 2 ejected (robust_decode)" in out
    assert "train_step" in out                   # span latency table
    assert "ejections{stream=train} = 2" in out  # close-time counter dump
    assert "suspicion heat" in out


def test_reporter_kind_filter_and_missing(tmp_path, capsys):
    from repro.obs.report import main
    fixture = os.path.join(FIXTURES, "run.jsonl")
    assert main([fixture, "--kind", "train"]) == 0
    out = capsys.readouterr().out
    assert "records: train=3" in out
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert main([str(empty)]) == 1


def test_reporter_summarize_handles_non_finite():
    from repro.obs.report import summarize
    s = summarize([
        {"kind": "train", "step": 0, "loss": None,
         "suspicion": [0.1, None]},
        {"kind": "train", "step": 1, "loss": 1.0,
         "suspicion": [0.2, 0.3]},
    ])
    assert s["loss"]["n"] == 1 and s["loss"]["mean"] == 1.0
    assert s["suspicion_by_worker"][1] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# Default histogram buckets sanity
# ---------------------------------------------------------------------------

def test_default_buckets_are_increasing():
    assert list(DEFAULT_MS_BUCKETS) == sorted(set(DEFAULT_MS_BUCKETS))
    assert math.isfinite(DEFAULT_MS_BUCKETS[-1])
