"""Model registry: uniform API over all families.

``build_model(cfg)`` returns a `Model` whose methods close over the config:
  init(key) -> params
  forward(params, batch) -> (logits, aux)
  loss(params, batch) -> scalar
  init_cache(batch_size, max_len) -> cache
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  input_specs(shape) -> ShapeDtypeStruct batch stand-ins (see launch.dryrun)

Plain-GQA decoder LMs additionally expose the paged serving path
(``supports_paged``):
  init_paged_cache(num_blocks, block_tokens) -> block-pool cache
  prefill_paged(params, cache, tokens, block_tables) -> (logits, cache)
  decode_step_paged(params, cache, tokens, positions, block_tables)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import encdec, lm
from repro.models.stack import paged_supported


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Callable
    # Paged serving path (repro.serve v2); None for families the block-pool
    # cache does not cover (enc-dec) — plain-GQA support is gated at call
    # time by stack.paged_supported via init_paged_cache.
    init_paged_cache: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None
    prefill_paged: Optional[Callable] = None

    @property
    def supports_paged(self) -> bool:
        return (self.init_paged_cache is not None
                and paged_supported(self.cfg))

    def input_specs(self, shape: InputShape, *, global_batch: int = None,
                    for_decode: bool = None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (the modality
        frontends' outputs included — the sanctioned stub)."""
        B = global_batch if global_batch is not None else shape.global_batch
        S = shape.seq_len
        decode = shape.is_decode if for_decode is None else for_decode
        i32 = jnp.int32
        if decode:
            specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
            return specs
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if self.cfg.num_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, self.cfg.num_patches, self.cfg.vit_dim), jnp.float32)
        if self.cfg.is_encdec:
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (B, self.cfg.encoder_seq_len, self.cfg.frontend_dim),
                jnp.float32)
        return specs


def build_model(cfg: ArchConfig, *, remat: str = "none") -> Model:
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init(key, cfg),
            forward=lambda p, b: encdec.forward(p, cfg, b, remat=remat),
            loss=lambda p, b: encdec.loss_fn(p, cfg, b, remat=remat),
            init_cache=lambda bs, ml: encdec.init_cache(cfg, bs, ml),
            decode_step=lambda p, c, t, pos: encdec.decode_step(p, cfg, c, t,
                                                                pos),
        )
    return Model(
        cfg=cfg,
        init=lambda key: lm.init(key, cfg),
        forward=lambda p, b: lm.forward(p, cfg, b, remat=remat),
        loss=lambda p, b: lm.loss_fn(p, cfg, b, remat=remat),
        init_cache=lambda bs, ml: lm.init_cache(cfg, bs, ml),
        decode_step=lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos),
        init_paged_cache=lambda nb, bt: lm.init_paged_cache(cfg, nb, bt),
        decode_step_paged=lambda p, c, t, pos, tab: lm.decode_step_paged(
            p, cfg, c, t, pos, tab),
        prefill_paged=lambda p, c, t, tab: lm.prefill_paged(p, cfg, c, t,
                                                            tab),
    )
