"""Decoder-only language model (dense / MoE / SSM / hybrid / VLM backbone).

VLM (``cfg.num_patches > 0``): the stub vision frontend supplies precomputed
patch embeddings (``batch["patch_embeds"]``, (B, num_patches, vit_dim)); a
2-layer MLP projector maps them to d_model and they replace the first
``num_patches`` positions of the sequence (masked out of the loss).  This is
the one sanctioned stub — the language backbone is fully implemented.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models import stack as ST


def init(key, cfg) -> dict:
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    params = {
        "embed": C.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "stack": ST.init_stack(ks[1], cfg),
        "final_norm": C.init_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = C.init_linear(ks[2], cfg.d_model, cfg.vocab_size, dt)
    if cfg.num_patches:
        h = cfg.d_model
        k1, k2 = jax.random.split(ks[3])
        params["projector"] = {
            "fc1": C.init_linear(k1, cfg.vit_dim, h, dt),
            "fc2": C.init_linear(k2, h, h, dt),
        }
    return params


def _embed_inputs(params, cfg, batch) -> jax.Array:
    x = C.embed(params["embed"], batch["tokens"])
    x = x * math.sqrt(cfg.d_model)
    if cfg.num_patches:
        pe = batch["patch_embeds"].astype(x.dtype)
        proj = C.linear(params["projector"]["fc2"],
                        jax.nn.gelu(C.linear(params["projector"]["fc1"], pe)))
        x = jnp.concatenate([proj, x[:, cfg.num_patches :]], axis=1)
    return x


def _logits(params, cfg, x) -> jax.Array:
    x = C.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = C.linear(params["lm_head"], x)
    return C.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def forward(params, cfg, batch, *, remat: str = "none") -> jax.Array:
    """Training/prefill forward: batch['tokens'] (B,S) -> logits (B,S,V)."""
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, _, aux = ST.stack_fwd(params["stack"], cfg, x, positions=positions,
                             remat=remat)
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg, batch, *, remat: str = "none") -> jax.Array:
    """Next-token cross-entropy (+ MoE aux); VLM patch positions masked."""
    logits, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]                        # (B,S) next tokens
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = jnp.ones_like(nll)
    if cfg.num_patches:
        pos = jnp.arange(nll.shape[1])[None]
        mask = (pos >= cfg.num_patches).astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    return ST.init_stack_cache(cfg, batch_size, max_len)


def decode_step(params, cfg, cache, tokens, pos):
    """One decode step (tokens (B,1), pos scalar) or a batched prefill
    (tokens (B,S0), pos = arange(S0) — one pass writes the whole prompt
    into the cache).  Returns (logits (B,S,V), new_cache)."""
    x = C.embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    x, new_cache, _ = ST.stack_fwd(params["stack"], cfg, x,
                                   positions=positions, cache=cache)
    return _logits(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# Paged serving path (repro.serve v2, DESIGN.md §11)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, num_blocks: int, block_tokens: int) -> dict:
    """Block-pool KV cache; see stack.init_stack_paged_cache (raises
    NotImplementedError for architectures the paged path does not cover)."""
    return ST.init_stack_paged_cache(cfg, num_blocks, block_tokens)


def decode_step_paged(params, cfg, cache, tokens, positions, block_tables):
    """One paged decode step with per-request positions.  tokens (B,1),
    positions (B,), block_tables (B, max_blocks) int32.
    Returns (logits (B,1,V), new_cache)."""
    x = C.embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    x, new_cache = ST.stack_fwd_paged(params["stack"], cfg, x,
                                      positions=positions,
                                      block_tables=block_tables, cache=cache)
    return _logits(params, cfg, x), new_cache


def prefill_paged(params, cfg, cache, tokens, block_tables):
    """Batched paged prefill: one forward pass over whole prompts (B,S0)
    aligned at position 0, k/v scattered into the block pool.
    Returns (logits (B,S0,V), new_cache)."""
    x = C.embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    positions = jnp.arange(tokens.shape[1])
    x, new_cache = ST.stack_fwd_paged(params["stack"], cfg, x,
                                      positions=positions,
                                      block_tables=block_tables, cache=cache,
                                      prefill=True)
    return _logits(params, cfg, x), new_cache
