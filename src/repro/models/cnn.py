"""The paper's CIFAR10 CNN (Table 3): 4 conv (32,32,64,64) + 2 dense (512) +
10-way softmax.  Dropout omitted (deterministic training; noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / jnp.sqrt(kh * kw * cin)
    return scale * jax.random.normal(key, (kh, kw, cin, cout))


def init_cnn(key, *, in_ch=3, num_classes=10, size=32) -> dict:
    ks = jax.random.split(key, 7)
    flat = (size // 4) * (size // 4) * 64
    return {
        "conv1": {"w": _conv_init(ks[0], 3, 3, in_ch, 32)},
        "conv2": {"w": _conv_init(ks[1], 3, 3, 32, 32)},
        "conv3": {"w": _conv_init(ks[2], 3, 3, 32, 64)},
        "conv4": {"w": _conv_init(ks[3], 3, 3, 64, 64)},
        "fc1": {"w": jax.random.normal(ks[4], (flat, 512)) / jnp.sqrt(flat),
                "b": jnp.zeros((512,))},
        "fc2": {"w": jax.random.normal(ks[5], (512, 512)) / jnp.sqrt(512.0),
                "b": jnp.zeros((512,))},
        "fc3": {"w": jax.random.normal(ks[6], (512, num_classes)) / 22.6,
                "b": jnp.zeros((num_classes,))},
    }


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def cnn_logits(params, x: jax.Array) -> jax.Array:
    """x: (B, H, W, C)."""
    h = jax.nn.relu(_conv(x, params["conv1"]["w"]))
    h = jax.nn.relu(_conv(h, params["conv2"]["w"]))
    h = _pool(h)
    h = jax.nn.relu(_conv(h, params["conv3"]["w"]))
    h = jax.nn.relu(_conv(h, params["conv4"]["w"]))
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["fc3"]["w"] + params["fc3"]["b"]


def cnn_loss(params, batch) -> jax.Array:
    logp = jax.nn.log_softmax(cnn_logits(params, batch["x"]))
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], 1))


def cnn_topk_accuracy(params, batch, k: int = 3) -> jax.Array:
    logits = cnn_logits(params, batch["x"])
    topk = jax.lax.top_k(logits, k)[1]
    return jnp.mean(jnp.any(topk == batch["y"][:, None], axis=1)
                    .astype(jnp.float32))


def build_cnn_model(**kw) -> Model:
    return Model(
        cfg=None,
        init=lambda key: init_cnn(key, **kw),
        forward=lambda p, b: (cnn_logits(p, b["x"]), jnp.zeros(())),
        loss=cnn_loss,
        init_cache=lambda bs, ml: {},
        decode_step=None,
    )
