"""Generic decoder-only stack: period-grouped ``lax.scan`` over layers with
pluggable mixers (GQA / MLA / Mamba2-SSD / hybrid) and FFNs (dense GLU / MoE).

Layer windows follow ``cfg.window_pattern`` (e.g. gemma3's 5×local:1×global).
The stack scans over *periods* — one pattern repetition per step, layers
inside a period unrolled so each position keeps its static window — with the
remainder layers unrolled as a tail.  This keeps the HLO small (one scan body)
while allowing heterogeneous per-layer KV-cache shapes (ring buffers for
windowed layers, full-length for global ones): essential for long_500k.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models import moe as M
from repro.models import ssm as S

# §Perf P9 (opt-in): Megatron-SP-style sequence sharding of the residual
# stream between layers — GSPMD then converts per-layer activation
# all-reduces into all-gather + reduce-scatter pairs and runs norms/
# residual adds 1/model_size.
SEQ_SHARD = os.environ.get("REPRO_SEQ_SHARD", "0") == "1"


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg) -> dict:
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {"ln1": C.init_norm(cfg.d_model, dt)}
    if cfg.is_ssm:
        p["mixer"] = S.init_mamba(ks[0], cfg)
        return p                                  # mamba2: block IS the layer
    if cfg.hybrid:
        p["mixer"] = C.init_attention(ks[0], cfg)
        p["mixer_ssm"] = S.init_mamba(ks[3], cfg)
        p["branch_norm_a"] = C.init_norm(cfg.d_model, dt)
        p["branch_norm_s"] = C.init_norm(cfg.d_model, dt)
    elif cfg.use_mla:
        p["mixer"] = C.init_mla(ks[0], cfg)
    else:
        p["mixer"] = C.init_attention(ks[0], cfg)
    p["ln2"] = C.init_norm(cfg.d_model, dt)
    p["ffn"] = M.init_moe(ks[1], cfg) if cfg.is_moe else C.init_mlp(ks[1], cfg)
    if cfg.use_post_norms:
        p["post_ln1"] = C.init_norm(cfg.d_model, dt)
        p["post_ln2"] = C.init_norm(cfg.d_model, dt)
    return p


def init_layer_cache(cfg, batch: int, max_len: int, window) -> dict:
    if cfg.is_ssm:
        return {"mixer": S.init_mamba_cache(cfg, batch)}
    cache = {}
    if cfg.hybrid:
        cache["mixer"] = C.init_attn_cache(cfg, batch, max_len, window)
        cache["mixer_ssm"] = S.init_mamba_cache(cfg, batch)
    elif cfg.use_mla:
        cache["mixer"] = C.init_mla_cache(cfg, batch, max_len)
    else:
        cache["mixer"] = C.init_attn_cache(cfg, batch, max_len, window)
    return cache


def layer_fwd(p, cfg, x, *, window, positions, cache=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = C.rmsnorm(p["ln1"], x, cfg.norm_eps)
    c = cache or {}
    if cfg.is_ssm:
        mix, nc = S.mamba_block(p["mixer"], cfg, h, cache=c.get("mixer"))
        return x + mix, ({"mixer": nc} if cache is not None else None), aux
    if cfg.hybrid:
        attn, nca = C.attention_block(p["mixer"], cfg, h, positions=positions,
                                      window=window, cache=c.get("mixer"))
        ssm, ncs = S.mamba_block(p["mixer_ssm"], cfg, h,
                                 cache=c.get("mixer_ssm"))
        mix = 0.5 * (C.rmsnorm(p["branch_norm_a"], attn, cfg.norm_eps)
                     + C.rmsnorm(p["branch_norm_s"], ssm, cfg.norm_eps))
        new_cache = ({"mixer": nca, "mixer_ssm": ncs}
                     if cache is not None else None)
    elif cfg.use_mla:
        mix, nc = C.mla_block(p["mixer"], cfg, h, positions=positions,
                              cache=c.get("mixer"))
        new_cache = {"mixer": nc} if cache is not None else None
    else:
        mix, nc = C.attention_block(p["mixer"], cfg, h, positions=positions,
                                    window=window, cache=c.get("mixer"))
        new_cache = {"mixer": nc} if cache is not None else None
    if cfg.use_post_norms:
        mix = C.rmsnorm(p["post_ln1"], mix, cfg.norm_eps)
    x = x + mix

    h = C.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        f, aux = M.moe_block(p["ffn"], cfg, h)
    else:
        f = C.mlp_block(p["ffn"], h)
    if cfg.use_post_norms:
        f = C.rmsnorm(p["post_ln2"], f, cfg.norm_eps)
    return x + f, new_cache, aux


# ---------------------------------------------------------------------------
# Stack: periods + tail
# ---------------------------------------------------------------------------

def _period_geometry(cfg):
    windows = cfg.layer_windows()
    P = max(len(cfg.window_pattern), 1)
    n_periods, tail = divmod(cfg.num_layers, P)
    return windows, P, n_periods, tail


def init_stack(key, cfg) -> dict:
    windows, P, n_periods, tail = _period_geometry(cfg)
    keys = jax.random.split(key, n_periods * P + tail)

    def init_period(ks):
        return {f"l{j}": init_layer(ks[j], cfg) for j in range(P)}

    blocks = jax.vmap(init_period)(
        keys[: n_periods * P].reshape(n_periods, P, -1))
    params = {"blocks": blocks}
    for j in range(tail):
        params[f"tail{j}"] = init_layer(keys[n_periods * P + j], cfg)
    return params


def init_stack_cache(cfg, batch: int, max_len: int) -> dict:
    windows, P, n_periods, tail = _period_geometry(cfg)

    def stackify(tree):
        return jax.tree.map(
            lambda x: jnp.zeros((n_periods,) + x.shape, x.dtype), tree)

    cache = {"blocks": {
        f"l{j}": stackify(init_layer_cache(cfg, batch, max_len, windows[j]))
        for j in range(P)}}
    for j in range(tail):
        cache[f"tail{j}"] = init_layer_cache(cfg, batch, max_len,
                                             windows[n_periods * P + j])
    return cache


# ---------------------------------------------------------------------------
# Paged-cache variant (repro.serve v2, DESIGN.md §11)
# ---------------------------------------------------------------------------

def paged_supported(cfg) -> bool:
    """Whether the paged serving path covers this architecture: plain global
    GQA decoder stacks only.  SSM/hybrid state is not paged, MLA caches
    latents (different pool shape), enc-dec has a second stream, and windowed
    ring buffers contradict the grow-only block table."""
    return (not (cfg.is_ssm or cfg.hybrid or cfg.use_mla or cfg.is_encdec)
            and all(w is None for w in cfg.layer_windows()))


def init_stack_paged_cache(cfg, num_blocks: int, block_tokens: int) -> dict:
    """Per-layer block pools with the same period-grouped structure as
    :func:`init_stack_cache`, so ``stack_fwd_paged`` scans identically."""
    if not paged_supported(cfg):
        raise NotImplementedError(
            f"paged KV cache unsupported for arch {cfg.name!r}: requires a "
            "plain global-attention decoder (no SSM/hybrid/MLA/enc-dec, no "
            "sliding windows); use init_stack_cache / the dense engine")
    windows, P, n_periods, tail = _period_geometry(cfg)

    def stackify(tree):
        return jax.tree.map(
            lambda x: jnp.zeros((n_periods,) + x.shape, x.dtype), tree)

    one = lambda: {"mixer": C.init_paged_kv(cfg, num_blocks, block_tokens)}
    cache = {"blocks": {f"l{j}": stackify(one()) for j in range(P)}}
    for j in range(tail):
        cache[f"tail{j}"] = one()
    return cache


def layer_fwd_paged(p, cfg, x, *, positions, block_tables, cache,
                    prefill=False):
    """Returns (x, new_cache).  MoE aux loss is irrelevant at inference and
    dropped."""
    h = C.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn = (C.attention_block_prefill_paged if prefill
            else C.attention_block_paged)
    mix, nc = attn(p["mixer"], cfg, h, positions=positions,
                   block_tables=block_tables, cache=cache["mixer"])
    if cfg.use_post_norms:
        mix = C.rmsnorm(p["post_ln1"], mix, cfg.norm_eps)
    x = x + mix
    h = C.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        f, _ = M.moe_block(p["ffn"], cfg, h)
    else:
        f = C.mlp_block(p["ffn"], h)
    if cfg.use_post_norms:
        f = C.rmsnorm(p["post_ln2"], f, cfg.norm_eps)
    return x + f, {"mixer": nc}


def stack_fwd_paged(params, cfg, x, *, positions, block_tables, cache,
                    prefill=False):
    """Paged analogue of :func:`stack_fwd` (cache always present).
    Returns (x, new_cache)."""
    windows, P, n_periods, tail = _period_geometry(cfg)

    def period_body(carry, xs):
        x = carry
        blk_p, blk_c = xs
        new_c = {}
        for j in range(P):
            x, nc = layer_fwd_paged(blk_p[f"l{j}"], cfg, x,
                                    positions=positions,
                                    block_tables=block_tables,
                                    cache=blk_c[f"l{j}"], prefill=prefill)
            new_c[f"l{j}"] = nc
        return x, new_c

    if n_periods > 0:
        x, new_blocks = jax.lax.scan(period_body, x,
                                     (params["blocks"], cache["blocks"]))
    else:
        new_blocks = {}
    new_cache = {"blocks": new_blocks}
    for j in range(tail):
        x, nc = layer_fwd_paged(params[f"tail{j}"], cfg, x,
                                positions=positions,
                                block_tables=block_tables,
                                cache=cache[f"tail{j}"], prefill=prefill)
        new_cache[f"tail{j}"] = nc
    return x, new_cache


def stack_fwd(params, cfg, x, *, positions, cache=None, remat: str = "none"):
    """Apply the full layer stack.  Returns (x, new_cache, aux_total)."""
    windows, P, n_periods, tail = _period_geometry(cfg)
    has_cache = cache is not None

    def period_body(carry, xs):
        x = carry
        blk_p, blk_c = xs if has_cache else (xs, {})
        new_c, aux = {}, jnp.zeros((), jnp.float32)
        for j in range(P):
            x, nc, a = layer_fwd(blk_p[f"l{j}"], cfg, x, window=windows[j],
                                 positions=positions,
                                 cache=blk_c.get(f"l{j}") if has_cache else None)
            if SEQ_SHARD and not has_cache and x.shape[1] > 1:
                x = C.shard_hint(x, (None, "model", None))
            if has_cache:
                new_c[f"l{j}"] = nc
            aux = aux + a
        return x, (new_c, aux) if has_cache else aux

    body = period_body
    if remat == "full":
        body = jax.checkpoint(period_body)
    elif remat == "dots":
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = (params["blocks"], cache["blocks"]) if has_cache else params["blocks"]
    if n_periods > 0:
        x, ys = jax.lax.scan(body, x, xs)
    else:
        ys = ({}, jnp.zeros((0,), jnp.float32)) if has_cache \
            else jnp.zeros((0,), jnp.float32)
    if has_cache:
        new_blocks, auxs = ys if n_periods > 0 else ({}, ys[1])
    else:
        new_blocks, auxs = None, ys
    aux_total = jnp.sum(auxs)

    new_cache = {"blocks": new_blocks} if has_cache else None
    for j in range(tail):
        w = windows[n_periods * P + j]
        x, nc, a = layer_fwd(params[f"tail{j}"], cfg, x, window=w,
                             positions=positions,
                             cache=cache.get(f"tail{j}") if has_cache else None)
        if has_cache:
            new_cache[f"tail{j}"] = nc
        aux_total = aux_total + a
    return x, new_cache, aux_total
