"""Mamba2 SSD (state-space duality) block — pure JAX, chunked algorithm.

Training/prefill uses the quadratic-within-chunk / linear-across-chunks SSD
decomposition (Dao & Gu 2024, §6): all chunk-local work is batched matmuls
(MXU friendly) and the cross-chunk recurrence is a tiny scan-free cumulative
product over num_chunks.  Decode uses the O(1) recurrent state update.

Shapes: x (B,S,d_model); internal heads H = d_inner/head_dim, state N,
head dim P; B/C projections are shared across heads (ngroups=1).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dtype_of, init_linear, linear, rmsnorm

SSD_CHUNK = 256


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def init_mamba(key, cfg) -> dict:
    dt = dtype_of(cfg)
    d, n, w = cfg.d_model, cfg.ssm_state, cfg.ssm_conv_width
    d_inner, nheads = _dims(cfg)
    conv_ch = d_inner + 2 * n
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * n + nheads          # z, x, B, C, dt
    return {
        "in_proj": init_linear(ks[0], d, d_in_proj, dt),
        "conv_w": (jax.random.normal(ks[1], (w, conv_ch)) / math.sqrt(w)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.full((nheads,), math.log(math.expm1(0.01)), jnp.float32),
        "A_log": jnp.log(1.0 + 15.0 * jax.random.uniform(ks[2], (nheads,),
                                                         jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dt)},
        "out_proj": init_linear(ks[3], d_inner, d, dt),
    }


def init_mamba_cache(cfg, batch: int) -> dict:
    dt = jnp.float32
    d_inner, nheads = _dims(cfg)
    conv_ch = d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch),
                          dtype_of(cfg)),
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via width-many shifted adds.  x: (B,S,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., T) -> (..., T, T) with out[i,j] = sum a[j+1..i], -inf above diag."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xbar, dA, B, C, *, chunk=SSD_CHUNK, init_state=None):
    """Chunked SSD. xbar: (b,S,h,p) dt-scaled inputs; dA: (b,S,h); B/C: (b,S,n).

    Returns (y (b,S,h,p), final_state (b,h,p,n)).  f32 throughout.
    """
    b, S, h, p = xbar.shape
    n = B.shape[-1]
    if S % chunk:
        chunk = S                                      # degenerate: one chunk
    nc = S // chunk
    xc = xbar.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    Ac = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b,h,nc,cs)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    A_cum = jnp.cumsum(Ac, axis=-1)                    # (b,h,nc,cs)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))                           # (b,h,nc,cs,cs)
    G = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)          # (b,nc,cs,cs)
    M = G[:, None] * L.transpose(0, 1, 2, 3, 4)        # (b,h,nc,cs,cs)
    Y_diag = jnp.einsum("bhcls,bcshp->bclhp", M, xc)

    # 2. per-chunk final states (no carry-in)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)    # (b,h,nc,cs)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_states, xc)

    # 3. cross-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([init_state[:, None], states], axis=1)  # (b,nc+1,...)
    chunk_sum = A_cum[..., -1]                         # (b,h,nc)
    decay_chunk = jnp.exp(_segsum(jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_in, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output contribution
    state_decay = jnp.exp(A_cum)                       # (b,h,nc,cs)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_in, state_decay)

    y = (Y_diag + Y_off).reshape(b, S, h, p)
    return y, final_state


def mamba_block(p, cfg, x, *, positions=None, cache: Optional[dict] = None,
                window=None):
    """Mamba2 block.  Training/prefill when cache is None; decode (S==1)
    otherwise.  Returns (out (B,S,d), new_cache)."""
    del positions, window
    B_, S, d = x.shape
    n, width = cfg.ssm_state, cfg.ssm_conv_width
    d_inner, nheads = _dims(cfg)
    hp = cfg.ssm_head_dim

    zxbcdt = linear(p["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * n :]        # (B,S,nheads)

    new_cache = None
    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    else:
        # decode: conv over [state, x_t]
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,width,C)
        out = sum(hist[:, i : i + 1] * p["conv_w"][i] for i in range(width))
        xbc = jax.nn.silu(out + p["conv_b"])
        new_conv = hist[:, 1:]

    xin = xbc[..., :d_inner].reshape(B_, S, nheads, hp)
    Bp = xbc[..., d_inner : d_inner + n]
    Cp = xbc[..., d_inner + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,h)
    A = -jnp.exp(p["A_log"])                           # (h,)
    dA = dt * A                                        # (B,S,h)
    xbar = xin.astype(jnp.float32) * dt[..., None]

    if cache is None:
        y, _ = ssd_chunked(xbar, dA, Bp, Cp)
    else:
        # recurrent step: state <- exp(dA)*state + xbar ⊗ B ; y = C·state
        state = cache["ssm"]
        dA1 = dA[:, 0]                                 # (B,h)
        xb1 = xbar[:, 0]                               # (B,h,p)
        Bn = Bp[:, 0].astype(jnp.float32)              # (B,n)
        Cn = Cp[:, 0].astype(jnp.float32)
        state = (jnp.exp(dA1)[..., None, None] * state
                 + jnp.einsum("bhp,bn->bhpn", xb1, Bn))
        y = jnp.einsum("bhpn,bn->bhp", state, Cn)[:, None]  # (B,1,h,p)
        new_cache = {"conv": new_conv, "ssm": state}

    y = y + p["D"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(p["norm"], y.astype(x.dtype), cfg.norm_eps)
    return linear(p["out_proj"], y), new_cache
