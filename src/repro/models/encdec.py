"""Encoder-decoder transformer (whisper backbone).

The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``batch["audio_embeds"]`` carries precomputed frame embeddings
(B, encoder_seq_len, frontend_dim).  Encoder: bidirectional self-attention
with sinusoidal positions.  Decoder: causal self-attention (cached) +
cross-attention to the encoder output (cached) + GLU MLP.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common as C


def _sinusoid(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None].astype(jnp.float32)
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg):
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 2)
    return {
        "ln1": C.init_norm(cfg.d_model, dt),
        "attn": C.init_attention(ks[0], cfg),
        "ln2": C.init_norm(cfg.d_model, dt),
        "mlp": C.init_mlp(ks[1], cfg),
    }


def _init_dec_layer(key, cfg):
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "ln1": C.init_norm(cfg.d_model, dt),
        "self_attn": C.init_attention(ks[0], cfg),
        "ln_x": C.init_norm(cfg.d_model, dt),
        "cross_attn": C.init_attention(ks[1], cfg),
        "ln2": C.init_norm(cfg.d_model, dt),
        "mlp": C.init_mlp(ks[2], cfg),
    }


def init(key, cfg) -> dict:
    dt = C.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "frontend_proj": C.init_linear(ks[2], cfg.frontend_dim, cfg.d_model, dt),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": C.init_norm(cfg.d_model, dt),
        "embed": C.init_embedding(ks[3], cfg.vocab_size, cfg.d_model, dt),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": C.init_norm(cfg.d_model, dt),
        "lm_head": C.init_linear(ks[4], cfg.d_model, cfg.vocab_size, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def _enc_attn(p, cfg, x):
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = C.linear(p["wq"], x).reshape(B, S, H, hd)
    k = C.linear(p["wk"], x).reshape(B, S, Kv, hd)
    v = C.linear(p["wv"], x).reshape(B, S, Kv, hd)
    pos = jnp.arange(S)
    out = C.attention_core(q, k, v, pos, pos, causal=False)
    return C.linear(p["wo"], out.reshape(B, S, H * hd))


def encode(params, cfg, audio_embeds, *, remat: str = "none") -> jax.Array:
    """(B, F, frontend_dim) -> (B, F, d_model)."""
    x = C.linear(params["frontend_proj"],
                 audio_embeds.astype(C.dtype_of(cfg)))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, lp):
        h = C.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        x = x + _enc_attn(lp["attn"], cfg, h)
        h = C.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + C.mlp_block(lp["mlp"], h), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return C.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _cross_attn(p, cfg, x, enc_kv):
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = C.linear(p["wq"], x).reshape(B, S, H, hd)
    k, v = enc_kv
    T = k.shape[1]
    out = C.attention_core(q, k, v, jnp.arange(S), jnp.arange(T), causal=False)
    return C.linear(p["wo"], out.reshape(B, S, H * hd))


def _dec_layer(lp, cfg, x, enc_kv, *, positions, cache=None):
    h = C.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    sa, nc = C.attention_block(lp["self_attn"], cfg, h, positions=positions,
                               window=None, cache=cache)
    x = x + sa
    h = C.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
    x = x + _cross_attn(lp["cross_attn"], cfg, h, enc_kv)
    h = C.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x + C.mlp_block(lp["mlp"], h), nc


def _cross_kv(lp, cfg, enc_out):
    B, T, _ = enc_out.shape
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = C.linear(lp["cross_attn"]["wk"], enc_out).reshape(B, T, Kv, hd)
    v = C.linear(lp["cross_attn"]["wv"], enc_out).reshape(B, T, Kv, hd)
    return k, v


def forward(params, cfg, batch, *, remat: str = "none"):
    """Full enc-dec training forward -> (logits (B,S,V), aux=0)."""
    enc_out = encode(params, cfg, batch["audio_embeds"], remat=remat)
    x = C.embed(params["embed"], batch["tokens"]) * math.sqrt(cfg.d_model)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        enc_kv = _cross_kv(lp, cfg, enc_out)
        x, _ = _dec_layer(lp, cfg, x, enc_kv, positions=positions)
        return x, None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = C.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = C.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch, *, remat: str = "none") -> jax.Array:
    logits, _ = forward(params, cfg, batch, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    return jnp.mean(nll)


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    """Self-attn KV per decoder layer + precomputed cross-attn KV (filled by
    ``prefill_cache`` from the encoder output)."""
    dt = C.dtype_of(cfg)
    L, Kv, hd, F = (cfg.num_layers, cfg.num_kv_heads, cfg.head_dim,
                    cfg.encoder_seq_len)
    return {
        "self": {
            "k": jnp.zeros((L, batch_size, max_len, Kv, hd), dt),
            "v": jnp.zeros((L, batch_size, max_len, Kv, hd), dt),
        },
        "cross": {
            "k": jnp.zeros((L, batch_size, F, Kv, hd), dt),
            "v": jnp.zeros((L, batch_size, F, Kv, hd), dt),
        },
    }


def prefill_cache(params, cfg, cache, audio_embeds):
    """Run the encoder and fill the cross-attention KV cache."""
    enc_out = encode(params, cfg, audio_embeds)

    def per_layer(lp):
        k, v = _cross_kv(lp, cfg, enc_out)
        return {"k": k, "v": v}

    cross = jax.vmap(per_layer)(params["dec"])
    return {**cache, "cross": cross}


def decode_step(params, cfg, cache, tokens, pos):
    """One decoder token against cached self/cross KV."""
    x = C.embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = C.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        sa, nc = C.attention_block(lp["self_attn"], cfg, h,
                                   positions=positions, window=None,
                                   cache={"k": ck, "v": cv})
        x = x + sa
        h = C.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        x = x + _cross_attn(lp["cross_attn"], cfg, h, (xk, xv))
        h = C.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + C.mlp_block(lp["mlp"], h)
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], cache["self"]["k"], cache["self"]["v"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    x = C.rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = C.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
