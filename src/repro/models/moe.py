"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch,
shared experts, load-balance auxiliary loss.

Dispatch is the TPU-idiomatic sort/segment scheme (no (T,E,C) one-hot
tensors): assignments are sorted by expert id, positions-within-expert are
computed from segment offsets, tokens scatter into a dense (E, C, d) buffer
that feeds two grouped einsums (the MXU path), and results gather back with
router weights.  Overflow beyond capacity C = ceil(T·k/E · capacity_factor)
is dropped (standard capacity-based MoE semantics).

Distribution (§Perf P6): when a mesh is active, tokens are pre-grouped by
data shard so the dispatch scatter/gather stays SHARD-LOCAL (GSPMD lowers a
cross-shard data-dependent scatter as an all-reduce of the whole expert
buffer — 100s of GB/layer at kimi scale); the cross-device movement then
happens inside the well-partitioned grouped einsums against expert-sharded
weights.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from repro.models.common import (data_axis_size, dtype_of, init_linear,
                                 linear, mlp_block, shard_hint)

_GROUPING = threading.local()


@contextlib.contextmanager
def no_data_grouping():
    """Disable the P6 data-shard token grouping.  The robust train step wraps
    its worker-vmap in this: each worker's tokens are already shard-local
    there, and regrouping would force a cross-shard reshard (measured 2×
    collective regression on deepseek train — §Perf P6)."""
    prev = getattr(_GROUPING, "off", False)
    _GROUPING.off = True
    try:
        yield
    finally:
        _GROUPING.off = prev


def _grouping_enabled() -> bool:
    return not getattr(_GROUPING, "off", False)


def init_moe(key, cfg) -> dict:
    dt = dtype_of(cfg)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    params = {
        "router": init_linear(ks[0], d, E, jnp.float32),
        "moe_wi": (scale * jax.random.normal(ks[1], (E, d, f))).astype(dt),
        "moe_wg": (scale * jax.random.normal(ks[2], (E, d, f))).astype(dt),
        "moe_wo": ((1.0 / jnp.sqrt(f)) * jax.random.normal(ks[3], (E, f, d))).astype(dt),
    }
    if cfg.num_shared_experts:
        from repro.models.common import init_mlp
        params["shared"] = init_mlp(ks[4], cfg,
                                    d_ff=cfg.d_ff * cfg.num_shared_experts)
    return params


def _moe_ffn(p, cfg, xt: jax.Array):
    """Routed-expert FFN over a flat token group.  xt: (T, d) ->
    ((T, d), aux scalar)."""
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok

    logits = linear(p["router"], xt.astype(jnp.float32))      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eids = jax.lax.top_k(probs, k)                      # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ----
    cap = int(-(-T * k // E) * cfg.capacity_factor) + 1       # C per expert
    flat_e = eids.reshape(-1)                                 # (T*k,)
    tok_of = jnp.repeat(jnp.arange(T), k)                     # (T*k,)
    order = jnp.argsort(flat_e)                               # stable
    se, st = flat_e[order], tok_of[order]
    counts = jnp.bincount(se, length=E)                       # (E,)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e, with f_e the
    # fraction of assignments routed to e (from `counts`, no (T,E) one-hot).
    me = jnp.mean(probs, axis=0)                              # (E,)
    fe = counts.astype(jnp.float32) / (T * k)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(me * fe)

    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - starts[se]                      # (T*k,)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                          # overflow slot

    buf = jnp.zeros((E, cap + 1, d), xt.dtype)
    buf = buf.at[se, slot].add(xt[st])                        # local scatter

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["moe_wg"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["moe_wi"])
    y = jnp.einsum("ecf,efd->ecd", h, p["moe_wo"])            # (E,cap+1,d)

    # ---- gather back with router weights ----
    gathered = y[se, slot]                                    # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w_sorted = gate.reshape(-1)[order]
    out = jnp.zeros((T, d), y.dtype).at[st].add(
        gathered * w_sorted[:, None].astype(y.dtype))
    return out, aux


def moe_block(p, cfg, x: jax.Array):
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    g = data_axis_size() if _grouping_enabled() else 0
    if g > 1 and T % g == 0 and T // g >= cfg.num_experts_per_tok:
        # group by data shard: dispatch scatter/gather stays shard-local,
        # capacity applies per group (same drop semantics at uniform load)
        xg = shard_hint(xt.reshape(g, T // g, d), ("data", None, None))
        out, aux = jax.vmap(lambda q: _moe_ffn(p, cfg, q))(xg)
        out = shard_hint(out, ("data", None, None)).reshape(T, d)
        aux = jnp.mean(aux)
    else:
        out, aux = _moe_ffn(p, cfg, xt)

    if "shared" in p:
        out = out + mlp_block(p["shared"], xt)
    return out.reshape(B, S, d), aux
