"""Shared model components: norms, embeddings, RoPE, chunked GQA/MLA
attention with sliding windows + softcaps, GLU MLPs, KV caches.

Everything is pure jnp over plain-dict pytrees (no flax): ``init_*`` builds
parameters, ``*_fwd`` applies them.  All code is vmap-safe (the trainer vmaps
whole-model grads over worker groups) and eval_shape-safe (the dry-run lowers
against ShapeDtypeStructs).
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

# Query-chunk length for attention: bounds the live (B,H,qc,T) score tensor so
# 32k-token prefills fit without a flash kernel (DESIGN.md §2 adaptation note).
ATTN_QUERY_CHUNK = 1024

# Opt-in fused flash-attention Pallas kernel (§Perf P5).  Off by default: the
# dry-run roofline reads dot FLOPs from the HLO, and a custom-call kernel is
# opaque to that accounting; on real TPUs set REPRO_FLASH_ATTN=1.
USE_FLASH_ATTN = os.environ.get("REPRO_FLASH_ATTN", "0") == "1"


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.compute_dtype)


def get_abstract_mesh():
    """Compat shim: ``jax.sharding.get_abstract_mesh`` is absent in the
    pinned jax 0.4.37 — fall back to the legacy ambient mesh set by
    ``with mesh:`` / the ``jax.set_mesh`` shim (an empty ``Mesh()`` when no
    mesh context is active, matching the modern empty AbstractMesh)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from repro.dist.compat import _ambient_mesh
    return _ambient_mesh()


def model_axis_size() -> int:
    """Size of the ambient mesh's 'model' axis (0 when no mesh is active —
    single-device tests / examples)."""
    am = get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return 0
    return am.shape["model"]


def data_axis_size() -> int:
    am = get_abstract_mesh()
    if am is None or am.empty or "data" not in am.axis_names:
        return 0
    return am.shape["data"]


def shard_hint(x: jax.Array, spec: tuple) -> jax.Array:
    """with_sharding_constraint when a mesh is active; no-op otherwise."""
    am = get_abstract_mesh()
    if am is None or am.empty:
        return x
    from jax.sharding import PartitionSpec as P
    names = set(am.axis_names)
    spec = tuple(s if (s is None or s in names) else None for s in spec)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Param initializers
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype) -> dict:
    scale = 1.0 / math.sqrt(d_in)
    return {"w": (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)}


def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def linear(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"]


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # broadcast heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (GQA + sliding window + softcap), query-chunked
# ---------------------------------------------------------------------------

def _attend(q, k, v, q_pos, k_pos, *, causal, window, cap, scale):
    """q: (B,Sq,H,hd) k/v: (B,T,Kv,hd); q_pos (Sq,), k_pos (T,) (-1=invalid)."""
    B, Sq, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    qg = q.reshape(B, Sq, Kv, rep, hd)
    # bf16 matmul inputs with f32 accumulation: MXU-native, and bf16 inputs
    # carry no extra information to justify f32 operand traffic (§Perf H2-b).
    s = jnp.einsum("bqkrh,btkh->bkrqt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    mask = (k_pos >= 0)[None, :]                       # (1, T) validity
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)                     # f32 softmax
    p = jnp.where(jnp.isnan(p), 0.0, p)                # fully-masked rows
    out = jnp.einsum("bkrqt,btkh->bqkrh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(v.dtype)


def attention_core(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                   cap=None, scale=None, chunk=ATTN_QUERY_CHUNK):
    """Query-chunked masked attention; see _attend for shapes."""
    B, Sq, H, hd = q.shape
    if scale is None:
        scale = hd ** -0.5
    if (USE_FLASH_ATTN and causal and Sq > 1 and Sq == k.shape[1]
            and jnp.issubdtype(q.dtype, jnp.floating)):
        # fused Pallas flash attention (§Perf P5); self-attention train/
        # prefill path (q_pos == k_pos == arange).
        from repro.kernels.flashattn.ops import flash_attention
        return flash_attention(q, k, v, causal=True, window=window,
                               cap=cap, scale=scale)
    # §Perf H1: head counts not divisible by the model axis (starcoder 36,
    # hymba 25, whisper 20 on a 16-way axis) leave the score/AV matmuls
    # replicated across the whole model axis (~16x overcompute).  Expanding
    # GQA and zero-padding heads to the next multiple makes the head dim
    # shardable: <=33% padding waste instead of 16x replication.
    # (decode steps — Sq == 1 — skip it: the score matmul is tiny and
    # re-materializing a padded KV cache every token would cost far more
    # than the replicated compute it saves.)
    ms = model_axis_size()
    if ms > 1 and H % ms and Sq > 1:
        Kv = k.shape[2]
        rep = H // Kv
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        Hp = -(-H // ms) * ms
        padn = Hp - H
        q = jnp.pad(q, ((0, 0), (0, 0), (0, padn), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, padn), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, padn), (0, 0)))
        hint = (None, None, "model", None)
        q, k, v = (shard_hint(t, hint) for t in (q, k, v))
        out = attention_core(q, k, v, q_pos, k_pos, causal=causal,
                             window=window, cap=cap, scale=scale, chunk=chunk)
        return out[:, :, :H]
    if Sq <= chunk or Sq % chunk != 0:
        return _attend(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       cap=cap, scale=scale)
    nc = Sq // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(nc, chunk)

    def one(args):
        qi, pi = args
        return _attend(qi, k, v, pi, k_pos, causal=causal, window=window,
                       cap=cap, scale=scale)

    out = jax.lax.map(one, (qc, pc))                   # (nc, B, chunk, H, hd)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# GQA attention block with ring-buffer KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    d, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": init_linear(ks[0], d, H * hd, dt),
        "wk": init_linear(ks[1], d, Kv * hd, dt),
        "wv": init_linear(ks[2], d, Kv * hd, dt),
        "wo": init_linear(ks[3], H * hd, d, dt),
    }


def init_attn_cache(cfg, batch: int, max_len: int, window: Optional[int]) -> dict:
    dt = dtype_of(cfg)
    size = min(window, max_len) if window else max_len
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, Kv, hd), dt),
        "v": jnp.zeros((batch, size, Kv, hd), dt),
    }


def _cache_positions(size: int, pos: jax.Array,
                     window: Optional[int]) -> jax.Array:
    """Global position stored in each ring slot at decode position ``pos``.

    Un-windowed caches are absolute: slot s holds position s (valid iff
    s <= pos).  Windowed ring buffers of size W: slot s holds the largest
    p <= pos with p ≡ s (mod W); never-written slots map to negative
    (invalid) positions — this also covers the not-yet-wrapped phase
    (pos < W), where it reduces to the absolute rule.
    """
    s = jnp.arange(size)
    if window is None:
        return jnp.where(s <= pos, s, -1)
    p = pos - ((pos - s) % size)
    return jnp.where(p >= 0, p, -1)


def attention_block(p, cfg, x, *, positions, window, cache=None):
    """x: (B,S,d).  Training (no cache) when cache is None; cached otherwise:
    decode (S==1, positions (1,)) or batched prefill (S==S0 contiguous
    positions, S0 <= the layer's ring size — engine-gated)."""
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, Kv, hd)
    v = linear(p["wv"], x).reshape(B, S, Kv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = attention_core(q, k, v, positions, positions, causal=True,
                             window=window, cap=cfg.attn_logit_softcap)
        new_cache = None
    else:
        size = cache["k"].shape[1]
        start = positions[0]                # write offset (decode: the step)
        last = positions[-1]                # newest position now in the cache
        slot = start % size
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        k_pos = _cache_positions(size, last, window)
        out = attention_core(q, ck, cv, positions, k_pos, causal=True,
                             window=window, cap=cfg.attn_logit_softcap)
        new_cache = {"k": ck, "v": cv}
    return linear(p["wo"], out.reshape(B, S, H * hd)), new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (repro.serve v2, DESIGN.md §11)
# ---------------------------------------------------------------------------
# One global pool of fixed-size blocks per layer; requests own disjoint block
# lists via per-request block tables (B, max_blocks) int32.  Block 0 is the
# reserved null/trash block: inactive batch slots carry an all-zero table row
# and scatter their k/v there — its contents are finite garbage that active
# requests never attend to (unused table-tail gathers of block 0 fall beyond
# the per-request validity mask, so softmax weighs them exactly 0).

def init_paged_kv(cfg, num_blocks: int, block_tokens: int) -> dict:
    dt = dtype_of(cfg)
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((num_blocks, block_tokens, Kv, hd), dt),
        "v": jnp.zeros((num_blocks, block_tokens, Kv, hd), dt),
    }


def _attend_paged(q, k, v, pos, *, cap, scale=None):
    """Decode attention with per-request lengths.  q: (B,1,H,hd); k/v:
    (B,T,Kv,hd) gathered per-request views; pos: (B,) newest position of
    each request.  Same einsum contractions / f32 softmax / NaN guard as
    :func:`_attend`, so paged and dense decode agree bit-for-bit — the only
    change is the validity mask going per-request (B,T)."""
    B, Sq, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, Sq, Kv, rep, hd)
    s = jnp.einsum("bqkrh,btkh->bkrqt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    mask = jnp.arange(T)[None, :] <= pos[:, None]      # (B, T) causal+validity
    s = jnp.where(mask[:, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)                     # f32 softmax
    p = jnp.where(jnp.isnan(p), 0.0, p)                # fully-masked rows
    out = jnp.einsum("bkrqt,btkh->bqkrh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(v.dtype)


def attention_block_paged(p, cfg, x, *, positions, block_tables, cache):
    """One paged decode step.  x: (B,1,d); positions: (B,) per-request write
    position; block_tables: (B, max_blocks) int32; cache: the layer's block
    pool {"k","v"}: (N, bt, Kv, hd).  Scatter-writes the new k/v at
    (table[pos//bt], pos%bt) then attends over the gathered per-request
    view.  Global (un-windowed) layers only — see stack.paged_supported."""
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, Kv, hd)
    v = linear(p["wv"], x).reshape(B, S, Kv, hd)
    q = rope(q, positions[:, None], cfg.rope_theta)
    k = rope(k, positions[:, None], cfg.rope_theta)

    bt = cache["k"].shape[1]
    blk = jnp.take_along_axis(block_tables, (positions // bt)[:, None],
                              axis=1)[:, 0]            # (B,)
    off = positions % bt
    ck = cache["k"].at[blk, off].set(k[:, 0])
    cv = cache["v"].at[blk, off].set(v[:, 0])
    T = block_tables.shape[1] * bt
    keys = ck[block_tables].reshape(B, T, Kv, hd)
    vals = cv[block_tables].reshape(B, T, Kv, hd)
    out = _attend_paged(q, keys, vals, positions, cap=cfg.attn_logit_softcap)
    return linear(p["wo"], out.reshape(B, S, H * hd)), {"k": ck, "v": cv}


def attention_block_prefill_paged(p, cfg, x, *, positions, block_tables,
                                  cache):
    """Batched paged prefill.  x: (B,S0,d) whole prompts aligned at position
    0; positions: (S0,) = arange(S0).  Ordinary causal self-attention over
    the prompt (no cache read), with the computed k/v scattered into the
    block pool so subsequent paged decode steps see them."""
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, Kv, hd)
    v = linear(p["wv"], x).reshape(B, S, Kv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = attention_core(q, k, v, positions, positions, causal=True,
                         window=None, cap=cfg.attn_logit_softcap)

    bt = cache["k"].shape[1]
    blk = block_tables[:, positions // bt]             # (B, S0)
    off = jnp.broadcast_to(positions % bt, (B, S))
    ck = cache["k"].at[blk, off].set(k)
    cv = cache["v"].at[blk, off].set(v)
    return linear(p["wo"], out.reshape(B, S, H * hd)), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2) with latent KV cache
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.num_heads
    nope, rdim, vdim, rank = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                              cfg.v_head_dim, cfg.kv_lora_rank)
    return {
        "wq": init_linear(ks[0], d, H * (nope + rdim), dt),
        "wkv_a": init_linear(ks[1], d, rank, dt),          # latent down-proj
        "wk_rope": init_linear(ks[2], d, rdim, dt),        # shared rope key
        "wk_b": init_linear(ks[3], rank, H * nope, dt),    # latent -> keys
        "wv_b": init_linear(ks[4], rank, H * vdim, dt),    # latent -> values
        "wo": init_linear(ks[5], H * vdim, d, dt),
    }


def init_mla_cache(cfg, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg)
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dt),
    }


def _mla_attend(cfg, q_nope, q_rope, k_nope, v, krope, q_pos, k_pos):
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bqhn,bthn->bhqt", q_nope, k_nope,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhr,btr->bhqt", q_rope, krope,
                      preferred_element_type=jnp.float32)) * scale
    mask = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos[:, None])
    s = jnp.where(mask[None, None], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    pattn = jnp.where(jnp.isnan(pattn), 0.0, pattn)
    out = jnp.einsum("bhqt,bthv->bqhv", pattn.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _mla_attend_chunked(p, cfg, q_nope, q_rope, ckv, krope, q_pos, k_pos,
                        chunk=ATTN_QUERY_CHUNK):
    B, Sq, H = q_nope.shape[:3]
    T = ckv.shape[1]
    # Expand latent -> per-head keys/values ONCE (chunk-invariant); only the
    # (B,H,chunk,T) score tensor is re-materialized per query chunk.
    k_nope = linear(p["wk_b"], ckv).reshape(B, T, H, cfg.qk_nope_head_dim)
    v = linear(p["wv_b"], ckv).reshape(B, T, H, cfg.v_head_dim)
    if Sq <= chunk or Sq % chunk != 0:
        return _mla_attend(cfg, q_nope, q_rope, k_nope, v, krope, q_pos, k_pos)
    nc = Sq // chunk

    def one(args):
        qn, qr, pi = args
        return _mla_attend(cfg, qn, qr, k_nope, v, krope, pi, k_pos)

    split = lambda a: a.reshape(B, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    out = jax.lax.map(one, (split(q_nope), split(q_rope),
                            q_pos.reshape(nc, chunk)))
    return out.swapaxes(0, 1).reshape(B, Sq, *out.shape[3:])


def mla_block(p, cfg, x, *, positions, cache=None, window=None):
    del window                                          # MLA archs are global
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = linear(p["wq"], x).reshape(B, S, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv_new = linear(p["wkv_a"], x)                     # (B,S,rank)
    krope_new = rope(linear(p["wk_rope"], x)[:, :, None], positions,
                     cfg.rope_theta)[:, :, 0]           # (B,S,rdim)

    if cache is None:
        out = _mla_attend_chunked(p, cfg, q_nope, q_rope, ckv_new, krope_new,
                                  positions, positions)
        new_cache = None
    else:
        start = positions[0]                # decode: the step; prefill: 0
        last = positions[-1]                # newest cached position
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new,
                                                  start, 1)
        krope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope_new,
                                                    start, 1)
        T = ckv.shape[1]
        k_pos = jnp.where(jnp.arange(T) <= last, jnp.arange(T), -1)
        out = _mla_attend_chunked(p, cfg, q_nope, q_rope, ckv, krope,
                                  positions, k_pos)
        new_cache = {"ckv": ckv, "krope": krope}
    out = linear(p["wo"], out.reshape(B, S, H * cfg.v_head_dim))
    return out, new_cache


# ---------------------------------------------------------------------------
# Dense GLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: Optional[int] = None) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": init_linear(ks[0], d, f, dt),
        "wg": init_linear(ks[1], d, f, dt),
        "wo": init_linear(ks[2], f, d, dt),
    }


def mlp_block(p, x: jax.Array) -> jax.Array:
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))
