"""The paper's MNIST MLP (Table 2): 784-128-128-10, ReLU, softmax output.
Used by the Byzantine-resilience experiment benchmarks (fig2/fig3/fig4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def init_mlp_classifier(key, dims=(784, 128, 128, 10)) -> dict:
    params = {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"fc{i + 1}"] = {
            "w": jax.random.normal(keys[i], (din, dout)) / jnp.sqrt(din),
            "b": jnp.zeros((dout,)),
        }
    return params


def mlp_logits(params, x: jax.Array) -> jax.Array:
    h = x.reshape(x.shape[0], -1)
    n = len(params)
    for i in range(1, n + 1):
        h = h @ params[f"fc{i}"]["w"] + params[f"fc{i}"]["b"]
        if i < n:
            h = jax.nn.relu(h)
    return h


def mlp_loss(params, batch) -> jax.Array:
    logits = mlp_logits(params, batch["x"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], 1))


def mlp_accuracy(params, batch) -> jax.Array:
    return jnp.mean(
        (jnp.argmax(mlp_logits(params, batch["x"]), -1) == batch["y"])
        .astype(jnp.float32))


def build_mlp_model(dims=(784, 128, 128, 10)) -> Model:
    """Model-API wrapper so the Trainer/benchmarks drive it uniformly."""
    return Model(
        cfg=None,
        init=lambda key: init_mlp_classifier(key, dims),
        forward=lambda p, b: (mlp_logits(p, b["x"]), jnp.zeros(())),
        loss=mlp_loss,
        init_cache=lambda bs, ml: {},
        decode_step=None,
    )
