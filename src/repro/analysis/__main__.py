"""CLI: ``python -m repro.analysis [paths...]``.

Prints one ``path:line: severity RULE: message [fix: hint]`` line per
finding plus a summary, exits 1 when any error-severity finding survives
noqa filtering.  ``--jsonl PATH`` additionally writes telemetry-compatible
records (``repro.defense.telemetry`` format: ``{"t", "kind", "step", ...}``)
so ``benchmarks/run.py --only analysis`` can trend per-rule counts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

from repro.analysis.engine import run_analysis
from repro.analysis.findings import Finding


def write_jsonl(findings: List[Finding], path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        for f in findings:
            rec = {"t": time.time(), "kind": "analysis", "step": 0}
            rec.update(f.to_record())
            fh.write(json.dumps(rec) + "\n")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static analysis: PRNG discipline, plugin "
                    "contracts, collective axes, Pallas layout")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--jsonl", metavar="PATH",
                    help="also write findings as telemetry-style JSONL")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the registry contract audit")
    ap.add_argument("--scan-modules", action="store_true",
                    help="import each FILE argument and audit the plugin "
                         "classes it defines (fixture/CI hook)")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    findings = run_analysis(paths, contracts=not args.no_contracts,
                            scan_modules=args.scan_modules)
    for f in findings:
        print(f.render())
    if args.jsonl:
        write_jsonl(findings, args.jsonl)

    errors = sum(1 for f in findings if f.severity == "error")
    warnings = len(findings) - errors
    print(f"repro.analysis: {errors} error(s), {warnings} warning(s) "
          f"in {len(paths)} path(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
