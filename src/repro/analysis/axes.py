"""Collective-axis analyzer (rules AXIS001-AXIS002).

Mesh axes are matched by NAME (``dist/sharding.py``): a typo'd axis-name
literal in a ``psum``/``all_gather``/``ppermute`` call fails only when the
exact mesh shape is exercised — usually a multi-device CI gap.  AXIS001
pins every axis-name string literal passed to a collective (jax.lax or the
repro ``dist.collectives`` helpers) to the sharding-module vocabulary.

AXIS002 checks ``shard_map`` wiring statically: when the wrapped function
is a plain local ``def`` and ``in_specs`` is a literal tuple, the tuple's
arity must equal the function's positional-parameter count (and a literal
``out_specs`` tuple must match the function's literal tuple returns).
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional

from repro.analysis.astutil import (ImportTable, literal_str_elements,
                                    resolve_call)
from repro.analysis.findings import Finding

# Fallback when repro.dist.sharding cannot import (vocabulary drift between
# the fallback and AXIS_VOCAB is caught by test_analysis.py).
_DEFAULT_VOCAB = frozenset({"data", "pod", "model", "tensor", "tp", "mp"})

# dotted origin -> (positional index of the axis-name argument, kwarg name)
_LAX_COLLECTIVES: Dict[str, tuple] = {
    "jax.lax.psum": (1, "axis_name"),
    "jax.lax.pmean": (1, "axis_name"),
    "jax.lax.pmax": (1, "axis_name"),
    "jax.lax.pmin": (1, "axis_name"),
    "jax.lax.all_gather": (1, "axis_name"),
    "jax.lax.all_to_all": (1, "axis_name"),
    "jax.lax.ppermute": (1, "axis_name"),
    "jax.lax.pshuffle": (1, "axis_name"),
    "jax.lax.psum_scatter": (1, "axis_name"),
    "jax.lax.axis_index": (0, "axis_name"),
    "jax.lax.axis_size": (0, "axis_name"),
    # repro.dist.collectives helpers (axis-name sequences by contract)
    "repro.dist.collectives.psum_axes": (1, "names"),
    "repro.dist.collectives.gather_workers": (1, "axes"),
    "repro.dist.collectives.all_to_all_scatter": (1, "axes"),
    "repro.dist.collectives.gather_slices": (1, "axes"),
    "repro.dist.collectives.worker_slice_index": (0, "axes"),
    "repro.dist.collectives.axis_size": (0, "axes"),
}

_SHARD_MAP_NAMES = frozenset({
    "jax.shard_map", "jax.experimental.shard_map.shard_map"})


def axis_vocabulary() -> FrozenSet[str]:
    """The repo's mesh-axis vocabulary (import-resolved, with fallback)."""
    try:
        from repro.dist.sharding import AXIS_VOCAB
        return frozenset(AXIS_VOCAB)
    except Exception:
        return _DEFAULT_VOCAB


def _axis_arg(call: ast.Call, pos: int, kwarg: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == kwarg:
            return kw.value
    if len(call.args) > pos:
        return call.args[pos]
    return None


class _FunctionIndex:
    """Positional-arity + literal-return info for every named def."""

    def __init__(self, tree: ast.Module):
        self.arity: Dict[str, int] = {}
        self.ret_arity: Dict[str, Optional[int]] = {}
        counts: Dict[str, int] = {}
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            counts[node.name] = counts.get(node.name, 0) + 1
            if node.args.vararg is not None:
                self.arity.pop(node.name, None)
                counts[node.name] += 1     # force ambiguity -> skipped
                continue
            self.arity[node.name] = len(node.args.posonlyargs) \
                + len(node.args.args)
            self.ret_arity[node.name] = _literal_return_arity(node)
        # A name bound by several defs is ambiguous: drop it.
        for name, n in counts.items():
            if n > 1:
                self.arity.pop(name, None)
                self.ret_arity.pop(name, None)


def _literal_return_arity(fn) -> Optional[int]:
    """Common arity of the function's OWN literal-tuple returns (None when
    any return is non-literal or arities disagree)."""
    arities = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Return):
            if node.value is None:
                return None
            if _owner_function(fn, node) is not fn:
                continue
            if isinstance(node.value, ast.Tuple):
                arities.add(len(node.value.elts))
            else:
                return None
    if len(arities) == 1:
        return arities.pop()
    return None


def _owner_function(root, target) -> ast.AST:
    """The innermost function containing ``target`` under ``root``."""
    owner = root

    def visit(node, current):
        nonlocal owner
        if node is target:
            owner = current
            return True
        nxt = node if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) \
            else current
        return any(visit(c, nxt) for c in ast.iter_child_nodes(node))

    visit(root, root)
    return owner


def analyze(path: str, tree: ast.Module) -> List[Finding]:
    imports = ImportTable(tree)
    vocab = axis_vocabulary()
    findings: List[Finding] = []
    fn_index = _FunctionIndex(tree)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        resolved = resolve_call(node, imports)
        if resolved in _LAX_COLLECTIVES:
            pos, kwarg = _LAX_COLLECTIVES[resolved]
            arg = _axis_arg(node, pos, kwarg)
            if arg is None:
                continue
            literals, _ = literal_str_elements(arg)
            for value, lineno in literals:
                if value not in vocab:
                    findings.append(Finding(
                        rule="AXIS001", path=path, line=lineno,
                        message=f"axis name {value!r} passed to "
                                f"{resolved.rsplit('.', 1)[-1]} is not in "
                                f"the dist/sharding.py vocabulary "
                                f"{sorted(vocab)}",
                        hint="use an axis name from "
                             "repro.dist.sharding.AXIS_VOCAB (or add the "
                             "new role there first)"))
        elif resolved in _SHARD_MAP_NAMES:
            findings.extend(_check_shard_map(path, node, fn_index))
    return findings


def _check_shard_map(path: str, call: ast.Call,
                     fn_index: _FunctionIndex) -> List[Finding]:
    if not call.args or not isinstance(call.args[0], ast.Name):
        return []
    fname = call.args[0].id
    findings: List[Finding] = []
    in_specs = _kwarg(call, "in_specs")
    out_specs = _kwarg(call, "out_specs")

    arity = fn_index.arity.get(fname)
    if arity is not None and isinstance(in_specs, ast.Tuple):
        if len(in_specs.elts) != arity:
            findings.append(Finding(
                rule="AXIS002", path=path, line=call.lineno,
                message=f"shard_map in_specs has {len(in_specs.elts)} "
                        f"entries but {fname}() takes {arity} positional "
                        "arguments",
                hint="give every wrapped-function argument exactly one "
                     "PartitionSpec"))

    ret = fn_index.ret_arity.get(fname)
    if ret is not None and isinstance(out_specs, ast.Tuple) \
            and len(out_specs.elts) != ret:
        findings.append(Finding(
            rule="AXIS002", path=path, line=call.lineno,
            message=f"shard_map out_specs has {len(out_specs.elts)} "
                    f"entries but {fname}() returns {ret} values",
            hint="match out_specs to the wrapped function's return tuple"))
    return findings


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None
