"""Finding records + the ``# repro: noqa[RULE]`` escape hatch.

Every analyzer emits :class:`Finding` values — one per violation, carrying
the rule id, severity, anchor location, message, and a fix hint.  Findings
are machine-readable by construction: ``to_record()`` produces the dict the
CLI prints as JSONL (compatible with ``repro.defense.telemetry``'s
one-record-per-line format, so ``benchmarks/run.py --only analysis`` can
trend per-rule counts across PRs).

Suppression is in-band and auditable: a ``# repro: noqa[RULE1,RULE2]``
comment on the flagged line silences exactly those rules there (a bare
``# repro: noqa`` silences all rules on the line); anything after the
bracket is the audit reason.  Contract findings anchor to the class/function
definition line, so the same mechanism covers them.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning")

# Registry of every rule id: severity + one-line meaning (DESIGN.md §10).
RULES: Dict[str, Tuple[str, str]] = {
    "PRNG001": ("error", "PRNG key consumed more than once (or consumed "
                         "inside a loop with the binding outside) without "
                         "an intervening split/fold_in"),
    "PRNG002": ("error", "jax.random.split result never used"),
    "PRNG003": ("error", "nondeterministic Python value (hash/id/time/"
                         "random) feeds a PRNG seed or key"),
    "PRNG004": ("error", "constant/argless jax.random.PRNGKey in library "
                         "code (seeds must be threaded, not baked in)"),
    "AXIS001": ("error", "collective axis-name literal outside the "
                         "dist/sharding.py axis vocabulary"),
    "AXIS002": ("error", "shard_map in_specs/out_specs arity does not "
                         "match the wrapped function"),
    "PALLAS001": ("error", "Pallas block shape lane dimension not a "
                           "multiple of the 128-lane tile"),
    "PALLAS002": ("error", "kernel layout cap constant redefined outside "
                           "its owning module"),
    "PALLAS003": ("error", "kernel layout cap invariant violated between "
                           "core/selection.py and kernel modules"),
    "CONTRACT001": ("error", "emits_scores metadata inconsistent with the "
                             "reduce_sharded_with_scores override"),
    "CONTRACT002": ("error", "has_kernel metadata inconsistent with "
                             "_reduce_pallas / the kernels/ module"),
    "CONTRACT003": ("error", "supports_streaming metadata inconsistent "
                             "with train/streaming.py's implemented rules"),
    "CONTRACT004": ("error", "uses_b/uses_q metadata inconsistent with "
                             "the params the rule actually reads"),
    "CONTRACT005": ("error", "attack closure does not match the "
                             "(key, u, step=None) signature contract"),
    "CONTRACT006": ("error", "topology param_names does not cover the "
                             "topology_params keys run() actually reads"),
    "CONTRACT007": ("error", "fused_gate metadata inconsistent with the "
                             "reduce_sharded_gated_with_scores override"),
    "CONTRACT008": ("error", "attack_allowlist/STREAMING_ATTACKS entry "
                             "names an unregistered attack"),
    "CONTRACT009": ("error", "serving paged-cache invariant violated "
                             "(block size vs Pallas lane constants, or the "
                             "reserved null block handed out)"),
    "CONTRACT010": ("error", "telemetry .log/.emit call site uses a record "
                             "kind not registered in repro/obs/schema.py"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer violation (machine-readable; sorts by location)."""
    rule: str                       # rule id, e.g. "PRNG001"
    path: str                       # file the finding anchors to
    line: int                       # 1-indexed anchor line
    message: str                    # what is wrong, concretely
    hint: str = ""                  # how to fix it (or how to noqa it)
    severity: str = ""              # "" = the rule's registered severity

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown analysis rule id {self.rule!r}")
        if not self.severity:
            object.__setattr__(self, "severity", RULES[self.rule][0])
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_record(self) -> dict:
        """JSONL record body (telemetry-writer compatible value types)."""
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": int(self.line),
                "message": self.message, "hint": self.hint}

    def render(self) -> str:
        tail = f"  [fix: {self.hint}]" if self.hint else ""
        return (f"{self.path}:{self.line}: {self.severity} "
                f"{self.rule}: {self.message}{tail}")


# ---------------------------------------------------------------------------
# noqa parsing
# ---------------------------------------------------------------------------

# "# repro: noqa" | "# repro: noqa[PRNG001]" | "# repro: noqa[A,B] reason"
_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\[(?P<rules>[A-Z0-9_,\s]+)\])?")


def noqa_rules_of_line(line: str) -> Optional[FrozenSet[str]]:
    """Rules suppressed by this source line's noqa comment.

    Returns None when the line carries no repro-noqa comment, an empty
    frozenset for a bare ``# repro: noqa`` (suppress everything on the
    line), or the frozen set of named rule ids.
    """
    m = _NOQA_RE.search(line)
    if m is None:
        return None
    rules = m.group("rules")
    if not rules:
        return frozenset()
    return frozenset(r.strip() for r in rules.split(",") if r.strip())


def apply_noqa(findings: Sequence[Finding],
               source_lines: Dict[str, List[str]]) -> List[Finding]:
    """Drop findings suppressed by a noqa comment on their anchor line.

    ``source_lines`` maps path -> file lines; findings for paths not in the
    map (e.g. synthesized anchors) pass through unsuppressed.
    """
    kept = []
    for f in findings:
        lines = source_lines.get(f.path)
        if lines is not None and 1 <= f.line <= len(lines):
            suppressed = noqa_rules_of_line(lines[f.line - 1])
            if suppressed is not None and (not suppressed
                                           or f.rule in suppressed):
                continue
        kept.append(f)
    return kept
