"""File collection + analyzer orchestration for ``python -m repro.analysis``.

The AST analyzers (prng/axes/layout/telemetry_kinds) are pure per-file
passes; the contract
analyzer imports the live registries.  Directory arguments are walked
recursively for ``*.py``, skipping ``__pycache__``, hidden directories, and
anything under a ``fixtures`` directory — the seeded-violation corpus in
``tests/fixtures/analysis/`` must stay analyzable on demand (explicit file
arguments are always analyzed) without failing the repo-wide run.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Sequence, Tuple

from repro.analysis import axes, layout, prng, telemetry_kinds
from repro.analysis.findings import Finding, apply_noqa

_SKIP_DIR_PARTS = frozenset({"__pycache__", "fixtures"})


def collect_files(paths: Sequence[str]) -> Tuple[List[str], bool]:
    """Expand path arguments to the .py files to analyze.

    Returns ``(files, saw_directory)``; explicit file arguments are always
    included, directory walks apply the skip rules.
    """
    files: List[str] = []
    saw_dir = False
    for p in paths:
        if os.path.isdir(p):
            saw_dir = True
            for root, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if not d.startswith(".") and d not in _SKIP_DIR_PARTS)
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        files.append(os.path.join(root, fn))
        else:
            files.append(p)
    seen = set()
    unique = []
    for f in files:
        key = os.path.abspath(f)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    return unique, saw_dir


def _is_library_code(path: str) -> bool:
    """Library code (PRNG004 applies): anything under src/repro."""
    norm = os.path.normpath(os.path.abspath(path)).replace("\\", "/")
    return "/src/repro/" in norm


def analyze_file(path: str, source: str) -> List[Finding]:
    """Run the per-file AST analyzers (noqa NOT yet applied)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="PRNG001", path=path, line=e.lineno or 1,
                        message=f"file does not parse: {e.msg}",
                        hint="fix the syntax error",
                        severity="error")]
    findings: List[Finding] = []
    findings.extend(prng.analyze(path, tree,
                                 library_code=_is_library_code(path)))
    findings.extend(axes.analyze(path, tree))
    findings.extend(layout.analyze(path, tree))
    findings.extend(telemetry_kinds.analyze(path, tree))
    return findings


def run_analysis(paths: Sequence[str], *, contracts: bool = True,
                 scan_modules: bool = False) -> List[Finding]:
    """Analyze ``paths`` and return noqa-filtered findings, sorted.

    ``contracts=True`` additionally audits the live plugin registries
    (CONTRACT*/PALLAS003) whenever a directory argument is present.
    ``scan_modules=True`` instead imports each explicit FILE argument and
    audits the plugin classes it defines (the broken-contract fixture
    path).
    """
    files, saw_dir = collect_files(paths)
    source_lines: Dict[str, List[str]] = {}
    findings: List[Finding] = []

    for path in files:
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
        except OSError as e:
            findings.append(Finding(
                rule="PRNG001", path=path, line=1,
                message=f"unreadable: {e}", hint="pass readable .py files",
                severity="error"))
            continue
        source_lines[path] = source.splitlines()
        findings.extend(analyze_file(path, source))

    if scan_modules:
        from repro.analysis import contracts as contracts_mod
        for path in files:
            findings.extend(contracts_mod.check_module(path))
    elif contracts and saw_dir:
        from repro.analysis import contracts as contracts_mod
        findings.extend(contracts_mod.check_registry())

    # Contract findings anchor to files we may not have read yet; load
    # them so class-def-line noqa comments apply there too.
    for f in findings:
        if f.path not in source_lines and os.path.isfile(f.path):
            try:
                with open(f.path, "r", encoding="utf-8") as fh:
                    source_lines[f.path] = fh.read().splitlines()
            except OSError:
                pass

    kept = apply_noqa(findings, source_lines)
    kept.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return kept
