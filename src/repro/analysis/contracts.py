"""Plugin-contract conformance analyzer (CONTRACT001-CONTRACT009, PALLAS003).

The Rule/Attack/Topology registries (DESIGN.md §6/§9) carry metadata the
whole stack dispatches on — ``emits_scores``, ``has_kernel``,
``supports_streaming``, ``fused_gate``, ``uses_b``/``uses_q``, attack
``step_aware``, topology ``param_names``.  Nothing else verifies that the
metadata matches the implementation; a drifted flag surfaces as a silent
wrong answer (a defense run scoring with the uninformative default) or a
mid-sweep crash.  This analyzer imports the registries and inspects every
registered plugin:

* CONTRACT001 — ``emits_scores`` <=> ``reduce_sharded_with_scores`` is
  overridden below :class:`AggregatorRule`.
* CONTRACT002 — ``has_kernel`` <=> ``_reduce_pallas`` is overridden AND the
  ``repro.kernels.*`` module it dispatches to is importable.
* CONTRACT003 — ``supports_streaming`` <=> the rule is in
  ``train/streaming.py``'s ``STREAMING_IMPL_RULES`` (the scan actually
  implements it).
* CONTRACT004 — ``uses_b``/``uses_q`` <=> the rule's own methods read
  ``params.b``/``params.q``.
* CONTRACT005 — every attack factory's closure matches the
  ``(key, u[, step=None])`` signature contract (3rd arg iff ``step_aware``).
* CONTRACT006 — topology ``param_names`` equals the ``topology_params``
  keys its ``run()`` actually reads.
* CONTRACT007 — ``fused_gate`` <=> ``reduce_sharded_gated_with_scores`` is
  overridden (the one-pass defense path, satellite routing metadata).
* CONTRACT008 — topology ``attack_allowlist`` / streaming
  ``STREAMING_ATTACKS`` entries name registered attacks.
* CONTRACT009 — paged serving-cache invariants: ``DEFAULT_BLOCK_TOKENS``
  fills whole TPU sublanes, divides ``DEFAULT_TILE_D``, and the block
  allocator never hands out (or takes back) the reserved null block 0.
* PALLAS003 — live cross-module layout invariants (COUNTS_LANES == 128,
  tile divisibility, selection caps ordered, ref oracles importable).

``check_registry()`` audits everything registered; ``check_module(path)``
imports one file and audits the plugin objects defined in it (the fixture
/ CI hook for deliberately-broken contracts).
"""
from __future__ import annotations

import importlib.util
import inspect
import os
import re
import sys
from typing import Iterable, List, Optional, Tuple

from repro.analysis.findings import Finding


def _anchor(obj) -> Tuple[str, int]:
    """(relative path, definition line) for a class/function anchor."""
    try:
        path = inspect.getsourcefile(obj) or "<unknown>"
        line = inspect.getsourcelines(obj)[1]
    except (OSError, TypeError):
        return "<unknown>", 1
    try:
        rel = os.path.relpath(path)
        if not rel.startswith(".."):
            path = rel
    except ValueError:
        pass
    return path, line


def _defining_class(cls: type, name: str) -> Optional[type]:
    for k in cls.__mro__:
        if name in k.__dict__:
            return k
    return None


def _overridden(cls: type, base: type, name: str) -> bool:
    """Is ``name`` implemented below ``base`` in ``cls``'s MRO?"""
    owner = _defining_class(cls, name)
    return owner is not None and owner is not base \
        and issubclass(owner, base)


def _own_source(cls: type, base: type) -> str:
    """Concatenated source of every method ``cls`` defines below ``base``
    (shared intermediate bases like _TrimFamilyRule count — their reads
    are the subclass's reads)."""
    chunks = []
    for k in cls.__mro__:
        if k is base or not issubclass(k, base):
            continue
        for obj in vars(k).values():
            fn = getattr(obj, "__func__", obj)
            if inspect.isfunction(fn):
                try:
                    chunks.append(inspect.getsource(fn))
                except (OSError, TypeError):
                    pass
    return "\n".join(chunks)


# ---------------------------------------------------------------------------
# Rule checks
# ---------------------------------------------------------------------------

def _check_rule(cls) -> List[Finding]:
    from repro.core.registry import AggregatorRule
    findings: List[Finding] = []
    path, line = _anchor(cls)
    name = getattr(cls, "name", cls.__name__)

    def finding(rule: str, msg: str, hint: str) -> None:
        findings.append(Finding(rule=rule, path=path, line=line,
                                message=f"rule {name!r}: {msg}", hint=hint))

    src = _own_source(cls, AggregatorRule)

    scores = _overridden(cls, AggregatorRule, "reduce_sharded_with_scores")
    if cls.emits_scores and not scores:
        finding("CONTRACT001",
                "emits_scores=True but reduce_sharded_with_scores is the "
                "uninformative base default",
                "override reduce_sharded_with_scores (or drop "
                "emits_scores)")
    elif scores and not cls.emits_scores:
        finding("CONTRACT001",
                "reduce_sharded_with_scores is overridden but "
                "emits_scores=False hides it from score_rules()",
                "set emits_scores = True")

    pallas = _overridden(cls, AggregatorRule, "_reduce_pallas")
    if cls.has_kernel and not pallas:
        finding("CONTRACT002",
                "has_kernel=True but _reduce_pallas is not implemented",
                "implement _reduce_pallas dispatching to repro.kernels.*")
    elif pallas and not cls.has_kernel:
        finding("CONTRACT002",
                "_reduce_pallas exists but has_kernel=False keeps "
                "backend='pallas' unreachable",
                "set has_kernel = True")
    if cls.has_kernel and pallas:
        owner = _defining_class(cls, "_reduce_pallas")
        try:
            psrc = inspect.getsource(owner.__dict__["_reduce_pallas"])
        except (OSError, TypeError):
            psrc = ""
        mods = set(re.findall(r"repro\.kernels[\w.]*", psrc))
        if not mods:
            finding("CONTRACT002",
                    "_reduce_pallas does not dispatch to a repro.kernels "
                    "module",
                    "import the kernel from repro.kernels.<rule>")
        for mod in mods:
            try:
                found = importlib.util.find_spec(mod) is not None
            except (ImportError, ValueError):
                found = False
            if not found:
                finding("CONTRACT002",
                        f"_reduce_pallas dispatches to {mod!r} which is "
                        "not importable",
                        "fix the kernel module path")

    fused = _overridden(cls, AggregatorRule,
                        "reduce_sharded_gated_with_scores")
    if getattr(cls, "fused_gate", False) and not fused:
        finding("CONTRACT007",
                "fused_gate=True but reduce_sharded_gated_with_scores is "
                "the two-pass base composition",
                "override the gated hook with a one-pass implementation "
                "(or drop fused_gate)")
    elif fused and not getattr(cls, "fused_gate", False):
        finding("CONTRACT007",
                "reduce_sharded_gated_with_scores is overridden but "
                "fused_gate=False mislabels the defense routing",
                "set fused_gate = True so the conformance metadata "
                "matches the one-pass path")

    if cls.supports_streaming:
        try:
            from repro.train.streaming import STREAMING_IMPL_RULES
        except Exception:
            STREAMING_IMPL_RULES = ()
        if name not in STREAMING_IMPL_RULES:
            finding("CONTRACT003",
                    "supports_streaming=True but train/streaming.py has "
                    "no streaming formulation for it "
                    f"(STREAMING_IMPL_RULES={sorted(STREAMING_IMPL_RULES)})",
                    "add the streaming formulation or drop "
                    "supports_streaming")

    reads_b = re.search(r"params\.b\b", src) is not None
    reads_q = re.search(r"params\.q\b", src) is not None
    for flag, reads, pname in (("uses_b", reads_b, "b"),
                               ("uses_q", reads_q, "q")):
        declared = getattr(cls, flag)
        if declared and not reads:
            finding("CONTRACT004",
                    f"{flag}=True but no method reads params.{pname}",
                    f"read self.params.{pname} or drop {flag}")
        elif reads and not declared:
            finding("CONTRACT004",
                    f"methods read params.{pname} but {flag}=False hides "
                    "the dependency from spec validation",
                    f"set {flag} = True")
    return findings


def _check_streaming_sync(rule_names: Iterable[str]) -> List[Finding]:
    """Reverse direction of CONTRACT003 (the declared side lives in
    :func:`_check_rule` so module scans cover it): every implemented
    streaming rule must be registered and declare supports_streaming."""
    from repro.core import registry
    from repro.train import streaming
    findings: List[Finding] = []
    impl = set(streaming.STREAMING_IMPL_RULES)
    names = set(rule_names)
    spath, _ = _anchor(streaming)
    for name in sorted(impl):
        if name not in names:
            findings.append(Finding(
                rule="CONTRACT003", path=spath, line=1,
                message=f"STREAMING_IMPL_RULES names unregistered rule "
                        f"{name!r}",
                hint="keep STREAMING_IMPL_RULES in sync with the "
                     "registry"))
            continue
        cls = registry.get_rule(name)
        if not cls.supports_streaming:
            path, line = _anchor(cls)
            findings.append(Finding(
                rule="CONTRACT003", path=path, line=line,
                message=f"train/streaming.py implements {name!r} but the "
                        "rule does not declare supports_streaming",
                hint="set supports_streaming = True"))
    return findings


# ---------------------------------------------------------------------------
# Attack checks
# ---------------------------------------------------------------------------

def _check_attack(spec) -> List[Finding]:
    from repro.core.attacks import AttackConfig
    findings: List[Finding] = []
    path, line = _anchor(spec.factory)

    def finding(msg: str, hint: str) -> None:
        findings.append(Finding(
            rule="CONTRACT005", path=path, line=line,
            message=f"attack {spec.name!r}: {msg}", hint=hint))

    try:
        closure = spec.factory(AttackConfig(name=spec.name,
                                            num_byzantine=2))
    except Exception as e:  # the factory itself is part of the contract
        finding(f"factory raised {type(e).__name__}: {e}",
                "factories must accept any AttackConfig")
        return findings
    try:
        params = list(inspect.signature(closure).parameters.values())
    except (TypeError, ValueError):
        finding("closure signature is not introspectable",
                "return a plain function/lambda")
        return findings

    positional = [p for p in params if p.kind in
                  (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    if len(positional) < 2:
        finding(f"closure takes {len(positional)} positional args; the "
                "contract is (key, u[, step=None])",
                "accept the PRNG key and the worker matrix")
    if spec.step_aware:
        step = next((p for p in positional[2:] if p.name == "step"), None)
        if step is None or step.default is not None:
            finding("step_aware=True but the closure lacks a third "
                    "'step=None' parameter",
                    "step-aware closures must accept step=None so "
                    "matrix-level tools can call them stepless")
    else:
        extra = [p for p in positional[2:]
                 if p.default is inspect.Parameter.empty]
        if extra:
            finding("closure requires more than (key, u) but "
                    "step_aware=False means make_attack only passes two",
                    "default the extra parameters or set step_aware=True")
    return findings


# ---------------------------------------------------------------------------
# Topology checks
# ---------------------------------------------------------------------------

_PARAM_READ_RE = re.compile(
    r"topology_params(?:\.get\(\s*[\"'](\w+)[\"']|\[\s*[\"'](\w+)[\"']\])")


def _check_topology(cls, attack_names: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    path, line = _anchor(cls)
    name = getattr(cls, "name", cls.__name__)
    try:
        src = inspect.getsource(cls)
    except (OSError, TypeError):
        src = ""

    reads = {a or b for a, b in _PARAM_READ_RE.findall(src)}
    declared = set(cls.param_names)
    for key in sorted(reads - declared):
        findings.append(Finding(
            rule="CONTRACT006", path=path, line=line,
            message=f"topology {name!r} reads topology_params[{key!r}] "
                    "without declaring it in param_names (spec "
                    "validation would reject it)",
            hint=f"add {key!r} to param_names"))
    for key in sorted(declared - reads):
        findings.append(Finding(
            rule="CONTRACT006", path=path, line=line,
            message=f"topology {name!r} declares param_names entry "
                    f"{key!r} that run() never reads",
            hint="drop the stale entry or consume the parameter"))

    registered = set(attack_names) | {"none", ""}
    allow = cls.attack_allowlist
    if allow is not None:
        for atk in allow:
            if atk.lower() not in registered:
                findings.append(Finding(
                    rule="CONTRACT008", path=path, line=line,
                    message=f"topology {name!r} allowlists unregistered "
                            f"attack {atk!r}",
                    hint="keep attack_allowlist entries registered"))
    return findings


def _check_streaming_attacks(attack_names: Iterable[str]) -> List[Finding]:
    from repro.train import streaming
    findings: List[Finding] = []
    path, _ = _anchor(streaming)
    registered = set(attack_names) | {"none", ""}
    for atk in streaming.STREAMING_ATTACKS:
        if atk.lower() not in registered:
            findings.append(Finding(
                rule="CONTRACT008", path=path, line=1,
                message=f"STREAMING_ATTACKS names unregistered attack "
                        f"{atk!r}",
                hint="keep STREAMING_ATTACKS in sync with the attack "
                     "registry"))
    return findings


# ---------------------------------------------------------------------------
# PALLAS003: live layout invariants
# ---------------------------------------------------------------------------

def _check_layout_invariants() -> List[Finding]:
    findings: List[Finding] = []

    def finding(mod, msg: str, hint: str) -> None:
        path, _ = _anchor(mod)
        findings.append(Finding(rule="PALLAS003", path=path, line=1,
                                message=msg, hint=hint))

    from repro.analysis.layout import LANE
    from repro.core import selection
    from repro.kernels import common
    from repro.kernels.trmean import kernel as trmean_kernel

    if trmean_kernel.COUNTS_LANES != LANE:
        finding(trmean_kernel,
                f"COUNTS_LANES={trmean_kernel.COUNTS_LANES} != the "
                f"{LANE}-lane TPU tile the counts row packs into",
                "COUNTS_LANES must stay 128 (one lane per worker)")
    if common.DEFAULT_TILE_D % LANE:
        finding(common,
                f"DEFAULT_TILE_D={common.DEFAULT_TILE_D} is not a "
                f"multiple of {LANE}",
                "keep the default dim tile lane-aligned")
    if selection._PAIRWISE_MAX_M > selection._NETWORK_MAX_M:
        finding(selection,
                f"_PAIRWISE_MAX_M={selection._PAIRWISE_MAX_M} exceeds "
                f"_NETWORK_MAX_M={selection._NETWORK_MAX_M}: stable "
                "ranks would claim fleets the sorting network rejects",
                "keep the pairwise cap <= the network cap")

    try:
        from repro.kernels.phocas import kernel as phocas_kernel
        if phocas_kernel.COUNTS_LANES != trmean_kernel.COUNTS_LANES:
            finding(phocas_kernel,
                    "phocas kernel COUNTS_LANES diverged from the "
                    "trmean owner value",
                    "import COUNTS_LANES from kernels/trmean/kernel.py")
    except ImportError as e:
        finding(trmean_kernel, f"phocas kernel not importable: {e}",
                "keep the kernel pair in sync")

    for pkg in ("trmean", "phocas", "krum"):
        mod = f"repro.kernels.{pkg}.ref"
        try:
            ref = importlib.import_module(mod)
        except ImportError as e:
            finding(trmean_kernel,
                    f"kernel oracle module {mod} not importable: {e}",
                    "every kernel package ships a ref.py oracle")
            continue
        if not any(n.endswith("_ref") and callable(getattr(ref, n))
                   for n in vars(ref)):
            finding(ref, f"{mod} exports no *_ref oracle function",
                    "name the oracle <kernel>_ref")
    return findings


def _check_serve_invariants() -> List[Finding]:
    """CONTRACT009: the paged serving cache's layout and allocator
    invariants (DESIGN.md §11) against the live modules."""
    findings: List[Finding] = []

    def finding(mod, msg: str, hint: str) -> None:
        path, _ = _anchor(mod)
        findings.append(Finding(rule="CONTRACT009", path=path, line=1,
                                message=msg, hint=hint))

    from repro.kernels import common
    from repro.serve import cache as serve_cache

    bt = serve_cache.DEFAULT_BLOCK_TOKENS
    if bt % common.SUBLANE:
        finding(serve_cache,
                f"DEFAULT_BLOCK_TOKENS={bt} is not a multiple of the f32 "
                f"TPU sublane ({common.SUBLANE})",
                "a KV block's token axis must fill whole (8, 128) tiles")
    if common.DEFAULT_TILE_D % bt:
        finding(serve_cache,
                f"DEFAULT_TILE_D={common.DEFAULT_TILE_D} is not a multiple "
                f"of DEFAULT_BLOCK_TOKENS={bt}",
                "a lane-tile of flattened KV rows must cover whole blocks")

    alloc = serve_cache.BlockAllocator(8)
    handed = alloc.alloc(alloc.free_blocks)
    if 0 in handed:
        finding(serve_cache,
                "BlockAllocator handed out block 0 (the reserved "
                "null/trash block inactive slots scatter into)",
                "the free list must start at block 1")
    try:
        serve_cache.BlockAllocator(8).free([0])
    except ValueError:
        pass
    else:
        finding(serve_cache,
                "BlockAllocator.free accepted block 0 back into the pool",
                "freeing the reserved null block must raise")
    return findings


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def check_registry() -> List[Finding]:
    """Audit every registered rule, attack, and topology + the live layout
    invariants.  Requires the repro package importable."""
    from repro.core import registry
    from repro.experiment import topology as topo_mod

    findings: List[Finding] = []
    rule_names = registry.available_rules()
    attack_names = registry.available_attacks()
    for name in rule_names:
        findings.extend(_check_rule(registry.get_rule(name)))
    findings.extend(_check_streaming_sync(rule_names))
    for name in attack_names:
        findings.extend(_check_attack(registry.get_attack_spec(name)))
    for name in topo_mod.available_topologies():
        findings.extend(_check_topology(topo_mod.get_topology(name),
                                        attack_names))
    findings.extend(_check_streaming_attacks(attack_names))
    findings.extend(_check_layout_invariants())
    findings.extend(_check_serve_invariants())
    return findings


def check_module(path: str) -> List[Finding]:
    """Import one Python file and audit the plugin objects it defines
    (AggregatorRule/Topology subclasses, AttackSpec instances) — without
    requiring registration, so broken-contract fixtures never pollute the
    process-wide registries."""
    from repro.core.registry import AggregatorRule, AttackSpec
    from repro.experiment.topology import Topology

    modname = "_repro_analysis_scan_" + \
        re.sub(r"\W", "_", os.path.abspath(path))
    spec = importlib.util.spec_from_file_location(modname, path)
    if spec is None or spec.loader is None:
        return [Finding(rule="CONTRACT001", path=path, line=1,
                        message="module not importable for contract scan",
                        hint="pass a Python file")]
    mod = importlib.util.module_from_spec(spec)
    # Registered in sys.modules so inspect can anchor findings to real
    # source lines (getsourcefile resolves classes via their module).
    sys.modules[modname] = mod
    try:
        spec.loader.exec_module(mod)
    except Exception as e:
        del sys.modules[modname]
        return [Finding(rule="CONTRACT001", path=path, line=1,
                        message=f"import failed during contract scan: "
                                f"{type(e).__name__}: {e}",
                        hint="contract fixtures must import cleanly")]

    findings: List[Finding] = []
    attack_names = ()
    try:
        from repro.core import registry
        attack_names = registry.available_attacks()
    except Exception:
        pass
    for obj in vars(mod).values():
        if isinstance(obj, type) and issubclass(obj, AggregatorRule) \
                and obj is not AggregatorRule \
                and obj.__module__ == modname:
            findings.extend(_check_rule(obj))
        elif isinstance(obj, type) and issubclass(obj, Topology) \
                and obj is not Topology and obj.__module__ == modname:
            findings.extend(_check_topology(obj, attack_names))
        elif isinstance(obj, AttackSpec):
            findings.extend(_check_attack(obj))
    del sys.modules[modname]
    # anchor module-scan findings to the scanned file, not the temp module
    rebased = []
    for f in findings:
        if os.path.abspath(f.path) == os.path.abspath(path):
            f = Finding(rule=f.rule, path=path, line=f.line,
                        message=f.message, hint=f.hint,
                        severity=f.severity)
        rebased.append(f)
    return rebased
