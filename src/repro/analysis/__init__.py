"""repro.analysis — repo-aware static-analysis pass (DESIGN.md §10).

This codebase has already shipped two statically-detectable bugs (the dead
1-way ``jax.random.split`` in ``gambler_attack``, the PYTHONHASHSEED-
dependent ``hash(str(shape))`` streaming-attack seeding), and the
Rule/Attack/Topology registries now carry metadata contracts that nothing
verified until a sweep crashed at runtime.  This package is the correctness
tooling that keeps those invariants honest as the repo grows:

* ``prng``      — PRNG-discipline AST checks (PRNG001..PRNG004);
* ``contracts`` — plugin-metadata conformance via import + inspect
                  (CONTRACT001..CONTRACT009, PALLAS003);
* ``axes``      — collective axis-name + shard_map spec checks
                  (AXIS001..AXIS002);
* ``layout``    — Pallas block-layout / cap-constant checks
                  (PALLAS001..PALLAS002);
* ``telemetry_kinds`` — telemetry record kinds at ``.log``/``.emit`` call
                  sites must be registered in ``repro/obs/schema.py``
                  (CONTRACT010).

Run it as ``python -m repro.analysis [paths]`` (non-zero exit on errors),
or programmatically via :func:`run_analysis`.  Audited false positives are
suppressed in place with ``# repro: noqa[RULE]  -- reason``.
"""
from repro.analysis.engine import run_analysis
from repro.analysis.findings import Finding, RULES

__all__ = ["run_analysis", "Finding", "RULES"]
