"""PRNG-discipline analyzer (rules PRNG001-PRNG004).

JAX keys are consume-once values: using the same key in two sampling calls
(or sampling with a key that was also split) yields correlated streams, and
a key consumed inside a loop whose binding lives outside the loop draws the
SAME stream every iteration.  Both bugs have shipped in this repo's history
(ISSUE 6 motivation), so the checks are deliberately conservative: only
key expressions the analyzer can identify syntactically (``key`` /
``keys[0]``) are tracked, ``fold_in``/``PRNGKey`` derivation calls never
count as consumption, and sibling branches of an ``if`` never conflict.

* PRNG001 — the same key expression consumed twice on one control-flow
  path, or consumed under a loop while bound outside it.
* PRNG002 — the result of ``jax.random.split`` is never used.
* PRNG003 — ``hash()`` / ``id()`` / ``time.*()`` / ``random.*`` /
  ``np.random.*`` flowing into ``PRNGKey``/``fold_in``/``seed=``
  (PYTHONHASHSEED- or wall-clock-dependent seeding).
* PRNG004 — argless or constant-literal ``jax.random.PRNGKey`` in library
  code (``src/repro``): library seeds must be threaded in by callers.
"""
from __future__ import annotations

import ast
import copy
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (ImportTable, assigned_names, const_int,
                                    dotted_name, resolve_call,
                                    walk_expr_calls)
from repro.analysis.findings import Finding

# jax.random callables that CONSUME the key passed to them.  Derivation
# calls (fold_in, PRNGKey, key, wrap_key_data, key_data, clone) are absent
# on purpose: deriving many streams from one key is the idiomatic pattern.
_CONSUMERS = frozenset({
    "ball", "bernoulli", "beta", "binomial", "bits", "categorical",
    "cauchy", "chisquare", "choice", "dirichlet", "double_sided_maxwell",
    "exponential", "f", "gamma", "generalized_normal", "geometric",
    "gumbel", "laplace", "loggamma", "logistic", "lognormal", "maxwell",
    "multivariate_normal", "normal", "orthogonal", "pareto", "permutation",
    "poisson", "rademacher", "randint", "rayleigh", "split", "t",
    "triangular", "truncated_normal", "uniform", "wald", "weibull_min",
})

_KEY_MAKERS = frozenset({"jax.random.PRNGKey", "jax.random.key"})
_SEED_FEEDERS = _KEY_MAKERS | frozenset({"jax.random.fold_in"})

# Nondeterministic sources that must never feed a seed (PRNG003).
_TIME_FNS = frozenset({"time.time", "time.time_ns", "time.monotonic",
                       "time.monotonic_ns", "time.perf_counter",
                       "time.perf_counter_ns"})


def _consumer_name(resolved: Optional[str]) -> Optional[str]:
    """The jax.random sampler name when ``resolved`` is a key consumer."""
    if resolved is None:
        return None
    if resolved.startswith("jax.random."):
        tail = resolved[len("jax.random."):]
        if tail in _CONSUMERS:
            return tail
    return None


def _key_expr_id(expr: ast.expr) -> Optional[str]:
    """Trackable identity of a key expression: ``name`` or ``name[3]``.

    Dynamic expressions (``keys[i]``, ``fold_in(key, x)``, attributes)
    return None and are skipped — per-iteration derivation is exactly the
    correct idiom, and variable subscripts cannot be compared statically.
    """
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Subscript) and isinstance(expr.value, ast.Name):
        idx = const_int(expr.slice)
        if idx is not None:
            return f"{expr.value.id}[{idx}]"
    return None


@dataclasses.dataclass
class _KeyState:
    """Liveness of one key id inside a scope walk."""
    consumed_line: Optional[int]    # last live consumption (None = fresh)
    bind_depth: int                 # loop depth where last bound/reset


class _ScopeWalker:
    """Walks one function (or the module top level) tracking key liveness."""

    def __init__(self, analyzer: "_PrngAnalyzer", params: List[str]):
        self.a = analyzer
        self.state: Dict[str, _KeyState] = {
            p: _KeyState(None, 0) for p in params}
        self.depth = 0
        self.loop_rebinds: List[Set[str]] = []
        self.reported: Set[Tuple[str, int]] = set()

    # -- state helpers ----------------------------------------------------

    def _bind(self, name: str) -> None:
        # Rebinding a name resets the whole family: ``ks = split(key, 4)``
        # invalidates any tracked ``ks[0]`` / ``ks[1]`` entries too.
        self.state[name] = _KeyState(None, self.depth)
        for k in [k for k in self.state if k.startswith(f"{name}[")]:
            self.state[k] = _KeyState(None, self.depth)

    def _consume(self, key_id: str, line: int, fn: str) -> None:
        st = self.state.get(key_id)
        if st is None:
            st = _KeyState(None, 0)
            self.state[key_id] = st
        if st.consumed_line is not None:
            self._report(key_id, line,
                         f"key {key_id!r} already consumed on line "
                         f"{st.consumed_line} is consumed again by "
                         f"jax.random.{fn}")
        elif self.depth > st.bind_depth \
                and not self._rebound_in_loop(key_id):
            self._report(key_id, line,
                         f"key {key_id!r} bound outside this loop is "
                         f"consumed by jax.random.{fn} every iteration "
                         "(identical stream each pass)")
        st.consumed_line = line

    def _rebound_in_loop(self, key_id: str) -> bool:
        """Is the key's base name rebound somewhere in an enclosing loop
        body?  ``key, sk = jax.random.split(key)`` inside the loop is the
        idiomatic advance — later iterations consume a fresh binding, so
        the every-iteration-identical-stream report does not apply."""
        base = key_id.split("[", 1)[0]
        return any(base in bound for bound in self.loop_rebinds)

    def _report(self, key_id: str, line: int, msg: str) -> None:
        if (key_id, line) in self.reported:
            return
        self.reported.add((key_id, line))
        self.a.findings.append(Finding(
            rule="PRNG001", path=self.a.path, line=line, message=msg,
            hint="split or fold_in the key per use (new_key, sub = "
                 "jax.random.split(key)), or fold in the loop index"))

    # -- statement walking ------------------------------------------------

    def walk_block(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self.walk_stmt(stmt)

    def walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                       # separate scope, analyzed apart
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._scan_calls(stmt)
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                for name in assigned_names(t):
                    self._bind(name)
            return
        if isinstance(stmt, ast.If):
            self._scan_calls_expr(stmt.test)
            self._walk_branches([stmt.body, stmt.orelse])
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_calls_expr(stmt.iter)
            self.depth += 1
            self.loop_rebinds.append(_names_bound_in(stmt.body))
            for name in assigned_names(stmt.target):
                self._bind(name)
            self.walk_block(stmt.body)
            self.loop_rebinds.pop()
            self.depth -= 1
            self.walk_block(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self._scan_calls_expr(stmt.test)
            self.depth += 1
            self.loop_rebinds.append(_names_bound_in(stmt.body))
            self.walk_block(stmt.body)
            self.loop_rebinds.pop()
            self.depth -= 1
            self.walk_block(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self.walk_block(stmt.body)
            for h in stmt.handlers:
                self.walk_block(h.body)
            self.walk_block(stmt.orelse)
            self.walk_block(stmt.finalbody)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_calls_expr(item.context_expr)
                if item.optional_vars is not None:
                    for name in assigned_names(item.optional_vars):
                        self._bind(name)
            self.walk_block(stmt.body)
            return
        self._scan_calls(stmt)

    def _walk_branches(self, blocks: List[List[ast.stmt]]) -> None:
        """Walk if/else arms on copies; merge survivors conservatively."""
        base = copy.deepcopy(self.state)
        merged: Dict[str, _KeyState] = dict(base)
        for block in blocks:
            self.state = copy.deepcopy(base)
            self.walk_block(block)
            if not _terminates(block):
                for k, st in self.state.items():
                    prev = merged.get(k)
                    # prefer a live consumption from any surviving arm
                    if prev is None or (st.consumed_line is not None
                                        and prev.consumed_line is None):
                        merged[k] = st
        self.state = merged

    # -- expression scanning ----------------------------------------------

    def _scan_calls(self, stmt: ast.stmt) -> None:
        for call in walk_expr_calls(stmt):
            self._handle_call(call)

    def _scan_calls_expr(self, expr: ast.expr) -> None:
        for call in walk_expr_calls(expr):
            self._handle_call(call)

    def _handle_call(self, call: ast.Call) -> None:
        fn = _consumer_name(resolve_call(call, self.a.imports))
        if fn is None or not call.args:
            return
        key_id = _key_expr_id(call.args[0])
        if key_id is None:
            return
        self._consume(key_id, call.lineno, fn)


def _names_bound_in(stmts: List[ast.stmt]) -> Set[str]:
    """Names (re)bound anywhere in a statement block, nested scopes
    excluded (a nested def's assignments bind in ITS scope, not here)."""
    bound: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            return
        if isinstance(node, (ast.Assign,)):
            for t in node.targets:
                bound.update(assigned_names(t))
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            bound.update(assigned_names(node.target))
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            bound.update(assigned_names(node.target))
        elif isinstance(node, ast.NamedExpr):
            bound.update(assigned_names(node.target))
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    bound.update(assigned_names(item.optional_vars))
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in stmts:
        visit(stmt)
    return bound


def _terminates(block: List[ast.stmt]) -> bool:
    """Does this block always leave the surrounding statement stream?"""
    return bool(block) and isinstance(
        block[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _PrngAnalyzer:
    def __init__(self, path: str, tree: ast.Module, imports: ImportTable,
                 library_code: bool):
        self.path = path
        self.tree = tree
        self.imports = imports
        self.library_code = library_code
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        self._check_scope(self.tree, params=[])
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                params = [a.arg for a in (args.posonlyargs + args.args
                                          + args.kwonlyargs)]
                self._check_scope(node, params=params)
            elif isinstance(node, ast.Lambda):
                self._check_lambda(node)
        for call in (c for n in ast.walk(self.tree)
                     for c in ([n] if isinstance(n, ast.Call) else [])):
            self._check_seed_sources(call)
            self._check_constant_key(call)
        return self.findings

    # -- PRNG001 ----------------------------------------------------------

    def _check_scope(self, scope, params: List[str]) -> None:
        walker = _ScopeWalker(self, params)
        walker.walk_block(scope.body)
        self._check_dead_splits(scope)

    def _check_lambda(self, node: ast.Lambda) -> None:
        # A lambda body is one expression: flag a key consumed twice in it.
        walker = _ScopeWalker(self, [a.arg for a in node.args.args])
        for call in walk_expr_calls(node.body):
            walker._handle_call(call)

    # -- PRNG002 ----------------------------------------------------------

    def _check_dead_splits(self, scope) -> None:
        loaded: Set[str] = set()
        for node in ast.walk(scope):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                loaded.add(node.id)

        for stmt in self._scope_stmts(scope):
            if isinstance(stmt, ast.Expr) and self._is_split(stmt.value):
                self.findings.append(Finding(
                    rule="PRNG002", path=self.path, line=stmt.lineno,
                    message="jax.random.split result is discarded",
                    hint="bind and use the subkeys, or delete the call"))
            elif isinstance(stmt, ast.Assign) and self._is_split(stmt.value):
                dead = [n for t in stmt.targets for n in assigned_names(t)
                        if n != "_" and n not in loaded]
                for name in dead:
                    self.findings.append(Finding(
                        rule="PRNG002", path=self.path, line=stmt.lineno,
                        message=f"split result {name!r} is never used",
                        hint="consume the subkey or drop it from the "
                             "split (dead splits usually mean a stream "
                             "was meant to be used)"))

    def _scope_stmts(self, scope):
        """Statements belonging to this scope only (no nested functions)."""
        stack = list(scope.body)
        while stack:
            stmt = stack.pop(0)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            yield stmt
            for field in ("body", "orelse", "finalbody"):
                stack.extend(getattr(stmt, field, []) or [])
            for h in getattr(stmt, "handlers", []) or []:
                stack.extend(h.body)

    def _is_split(self, expr: ast.expr) -> bool:
        return (isinstance(expr, ast.Call)
                and resolve_call(expr, self.imports) == "jax.random.split")

    # -- PRNG003 ----------------------------------------------------------

    def _check_seed_sources(self, call: ast.Call) -> None:
        resolved = resolve_call(call, self.imports)
        seed_exprs: List[ast.expr] = []
        if resolved in _SEED_FEEDERS:
            seed_exprs.extend(call.args)
            seed_exprs.extend(kw.value for kw in call.keywords)
        else:
            seed_exprs.extend(kw.value for kw in call.keywords
                              if kw.arg == "seed")
        for expr in seed_exprs:
            bad = self._nondeterministic_source(expr)
            if bad is not None:
                self.findings.append(Finding(
                    rule="PRNG003", path=self.path, line=expr.lineno,
                    message=f"nondeterministic {bad} feeds a PRNG "
                            "seed/key (varies per process/run)",
                    hint="derive the value from stable data instead "
                         "(e.g. zlib.crc32 of a path string, or a "
                         "threaded seed)"))

    def _nondeterministic_source(self, expr: ast.expr) -> Optional[str]:
        for call in [n for n in ast.walk(expr) if isinstance(n, ast.Call)]:
            name = dotted_name(call.func)
            if name in ("hash", "id"):
                return f"{name}()"
            if name is None:
                continue
            resolved = self.imports.expand(name)
            if resolved in _TIME_FNS:
                return f"{resolved}()"
            if resolved.startswith("random.") \
                    or resolved.startswith("numpy.random."):
                return f"{resolved}()"
        return None

    # -- PRNG004 ----------------------------------------------------------

    def _check_constant_key(self, call: ast.Call) -> None:
        if not self.library_code:
            return
        if resolve_call(call, self.imports) not in _KEY_MAKERS:
            return
        if not call.args and not call.keywords:
            self.findings.append(Finding(
                rule="PRNG004", path=self.path, line=call.lineno,
                message="argless jax.random.PRNGKey in library code",
                hint="thread the seed in from the caller"))
        elif len(call.args) == 1 and not call.keywords \
                and const_int(call.args[0]) is not None:
            self.findings.append(Finding(
                rule="PRNG004", path=self.path, line=call.lineno,
                message="constant-literal jax.random.PRNGKey("
                        f"{const_int(call.args[0])}) in library code",
                hint="thread the seed in from the caller (tests and "
                     "scripts may hard-code seeds; library code may not)"))


def analyze(path: str, tree: ast.Module, *, library_code: bool
            ) -> List[Finding]:
    """Run the PRNG-discipline rules over one parsed file."""
    return _PrngAnalyzer(path, tree, ImportTable(tree), library_code).run()
