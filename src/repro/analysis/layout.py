"""Pallas layout analyzer (rules PALLAS001-PALLAS002).

TPU vector memory tiles are (8, 128): a Pallas ``BlockSpec`` whose lane
(last) dimension is not a multiple of 128 (or the scalar/column special
case 1) wastes or breaks the tiling.  PALLAS001 checks every literal (or
same-file-constant) block shape.

The kernels also share hard caps that MUST stay in sync across modules —
``COUNTS_LANES`` (the trmean/phocas counts kernels pack m workers into one
128-lane row), ``_NETWORK_MAX_M``/``_PAIRWISE_MAX_M`` (sorting-network and
stable-rank fallbacks in ``core/selection.py``), ``DEFAULT_TILE_D``.
PALLAS002 enforces single-sourcing: each cap is assigned in exactly one
owning module and imported everywhere else, so the caps cannot silently
diverge between ``core/selection.py``, kernel bodies, and the ``ref.py``
oracles.  (The numeric cross-module invariants between the live values are
PALLAS003, checked at import time by ``repro.analysis.contracts``.)
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.astutil import (ImportTable, const_int,
                                    module_int_constants, resolve_call)
from repro.analysis.findings import Finding

LANE = 128

_BLOCKSPEC_NAMES = frozenset({
    "jax.experimental.pallas.BlockSpec",
    "jax.experimental.pallas.tpu.BlockSpec",
})

# Layout cap -> path suffix of the single module allowed to assign it.
LAYOUT_CONSTANT_OWNERS: Dict[str, str] = {
    "COUNTS_LANES": "src/repro/kernels/trmean/kernel.py",
    "DEFAULT_TILE_D": "src/repro/kernels/common.py",
    "SUBLANE": "src/repro/kernels/common.py",
    "DEFAULT_BLOCK_TOKENS": "src/repro/serve/cache.py",
    "_NETWORK_MAX_M": "src/repro/core/selection.py",
    "_PAIRWISE_MAX_M": "src/repro/core/selection.py",
}


def analyze(path: str, tree: ast.Module) -> List[Finding]:
    imports = ImportTable(tree)
    consts = module_int_constants(tree)
    findings: List[Finding] = []

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and resolve_call(node, imports) in _BLOCKSPEC_NAMES:
            findings.extend(_check_block_shape(path, node, consts))

    findings.extend(_check_constant_owners(path, tree))
    return findings


def _check_block_shape(path: str, call: ast.Call,
                       consts: Dict[str, int]) -> List[Finding]:
    shape = None
    if call.args:
        shape = call.args[0]
    for kw in call.keywords:
        if kw.arg == "block_shape":
            shape = kw.value
    if not isinstance(shape, (ast.Tuple, ast.List)) or not shape.elts:
        return []
    lane = const_int(shape.elts[-1], consts)
    # 1 is the scalar/column-block idiom (e.g. the krum kernel's (m, 1)
    # score output); anything else must fill whole 128-lane tiles.
    if lane is None or lane == 1 or lane % LANE == 0:
        return []
    return [Finding(
        rule="PALLAS001", path=path, line=shape.lineno,
        message=f"BlockSpec lane dimension {lane} is not a multiple of "
                f"the {LANE}-lane TPU tile",
        hint=f"pad the last block dimension to a multiple of {LANE} "
             "(see kernels/common.pad_lanes) or use 1 for scalar blocks")]


def _check_constant_owners(path: str, tree: ast.Module) -> List[Finding]:
    findings: List[Finding] = []
    norm = path.replace("\\", "/")
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if not isinstance(t, ast.Name) \
                    or t.id not in LAYOUT_CONSTANT_OWNERS:
                continue
            owner = LAYOUT_CONSTANT_OWNERS[t.id]
            if not norm.endswith(owner):
                findings.append(Finding(
                    rule="PALLAS002", path=path, line=node.lineno,
                    message=f"layout cap {t.id} is owned by {owner}; "
                            "redefining it here lets the caps silently "
                            "diverge",
                    hint=f"import {t.id} from its owning module instead"))
    return findings
