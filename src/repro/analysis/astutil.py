"""Shared AST plumbing for the analyzers: import-alias resolution and
small expression helpers.

The analyzers match calls by their *dotted origin* (``jax.random.normal``,
``jax.lax.psum``, ``jax.experimental.pallas.BlockSpec``) no matter how the
file imported them (``import jax``, ``from jax import random as jr``,
``from jax.random import normal``).  :class:`ImportTable` builds the local
name -> dotted-path map from a module's import statements;
:func:`resolve_call` turns a ``Call.func`` expression into that dotted
path.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple


class ImportTable:
    """Maps local names to the dotted module/attribute paths they alias."""

    def __init__(self, tree: ast.Module):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    # "import jax.numpy as jnp" binds jnp -> jax.numpy;
                    # "import jax.numpy" binds jax -> jax.
                    self.aliases[local] = a.name if a.asname \
                        else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def expand(self, dotted: str) -> str:
        """Expand the leading segment of a dotted name via the alias map."""
        head, _, rest = dotted.partition(".")
        base = self.aliases.get(head)
        if base is None:
            return dotted
        return f"{base}.{rest}" if rest else base


def dotted_name(expr: ast.expr) -> Optional[str]:
    """``a.b.c`` attribute chain as a string (None for anything else)."""
    parts: List[str] = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def resolve_call(call: ast.Call, imports: ImportTable) -> Optional[str]:
    """Dotted origin of a call's callee, alias-expanded."""
    name = dotted_name(call.func)
    return None if name is None else imports.expand(name)


def const_int(expr: ast.expr,
              module_consts: Optional[Dict[str, int]] = None) -> Optional[int]:
    """Static int value of an expression: a literal, a unary minus of one,
    or a Name bound to a module-level int constant."""
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int) \
            and not isinstance(expr.value, bool):
        return expr.value
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        inner = const_int(expr.operand, module_consts)
        return None if inner is None else -inner
    if isinstance(expr, ast.Name) and module_consts is not None:
        return module_consts.get(expr.id)
    return None


def module_int_constants(tree: ast.Module) -> Dict[str, int]:
    """Top-level ``NAME = <int literal>`` bindings of a module."""
    consts: Dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            v = const_int(node.value)
            if v is not None:
                consts[node.targets[0].id] = v
    return consts


def walk_expr_calls(node: ast.AST) -> List[ast.Call]:
    """Every Call in ``node``'s expression subtree, in source order,
    WITHOUT descending into nested function/class/lambda bodies (those are
    separate scopes, analyzed on their own)."""
    calls: List[ast.Call] = []

    def visit(n: ast.AST) -> None:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef, ast.Lambda)):
            return
        if isinstance(n, ast.Call):
            calls.append(n)
        for child in ast.iter_child_nodes(n):
            visit(child)

    for child in ast.iter_child_nodes(node):
        visit(child)
    if isinstance(node, ast.Call):
        calls.insert(0, node)
    return calls


def assigned_names(target: ast.expr) -> List[str]:
    """Plain names bound by an assignment target (nested tuples included;
    subscripts/attributes contribute nothing — they mutate, not bind)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return assigned_names(target.value)
    return []


def literal_str_elements(expr: ast.expr) -> Tuple[List[Tuple[str, int]], bool]:
    """String literals inside an axis argument.

    Returns ``(literals, exhaustive)`` where each literal is ``(value,
    lineno)`` and ``exhaustive`` says the expression was fully literal (a
    plain string or a tuple/list of strings) rather than something dynamic.
    """
    if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
        return [(expr.value, expr.lineno)], True
    if isinstance(expr, (ast.Tuple, ast.List)):
        out: List[Tuple[str, int]] = []
        exhaustive = True
        for elt in expr.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append((elt.value, elt.lineno))
            else:
                exhaustive = False
        return out, exhaustive
    return [], False
