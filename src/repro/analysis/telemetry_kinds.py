"""Telemetry record-kind analyzer (rule CONTRACT010).

Every record on the observability bus carries a ``kind`` that consumers
dispatch on (``repro/obs/schema.py`` is the registry).  A typo'd kind at a
``TelemetryWriter.log`` / ``Recorder.emit`` call site doesn't fail at
runtime — the writer happily serialises it — it silently forks the record
stream away from every reader.  CONTRACT010 pins the literal first
argument of each ``.log(...)``/``.emit(...)`` call whose shape matches the
bus signature (``.log(<str literal>, <step>, ...)``) to the SCHEMA
registry.

Scope is deliberately narrow to avoid false positives on unrelated
``.log`` methods (math, loggers): only attribute calls named ``log`` or
``emit`` with at least two positional arguments whose FIRST argument is a
string literal are checked.  ``logging``-style calls pass a format string
(not a registered kind) but also take the message first and no step —
they virtually never collide; a genuine collision can be silenced with
``# repro: noqa[CONTRACT010]``.
"""
from __future__ import annotations

import ast
from typing import FrozenSet, List

from repro.analysis.findings import Finding

_METHOD_NAMES = frozenset({"log", "emit"})


def known_kinds() -> FrozenSet[str]:
    """The registered kind vocabulary (import-resolved lazily so the
    analyzer itself has no import-time dependency on the obs package)."""
    from repro.obs.schema import SCHEMA
    return frozenset(SCHEMA)


def analyze(path: str, tree: ast.Module) -> List[Finding]:
    kinds = known_kinds()
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in _METHOD_NAMES):
            continue
        if len(node.args) < 2:
            continue
        first = node.args[0]
        if not (isinstance(first, ast.Constant)
                and isinstance(first.value, str)):
            continue
        kind = first.value
        if kind not in kinds:
            findings.append(Finding(
                rule="CONTRACT010", path=path, line=node.lineno,
                message=f".{fn.attr}() call uses unregistered telemetry "
                        f"kind {kind!r} (known: {', '.join(sorted(kinds))})",
                hint="register the kind in repro/obs/schema.py SCHEMA, or "
                     "fix the typo at the call site"))
    return findings
