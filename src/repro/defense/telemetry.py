"""Structured per-step JSONL telemetry for the defense subsystem.

One record per line, machine-readable, append-only — the format every
consumer path (sync trainer, async SGD, streaming scan, serving) shares:

    {"t": <unix time>, "kind": "train", "step": 12, "loss": 0.41,
     "suspicion": [...], "reputation": [...], "active": [...], "q_hat": 2}

``TelemetryWriter`` is deliberately boring: stdlib-only, no-op when no path
is configured (so hot loops can call ``log`` unconditionally), converts jax
/ numpy values to plain JSON types, and flushes per record so a crashed or
killed run keeps everything written so far.  ``read_jsonl`` is the matching
loader used by tests and offline analysis.
"""
from __future__ import annotations

import json
import os
import time
from typing import IO, Optional

import numpy as np


#: Clamp for ±inf: the largest float64 that survives a strict-JSON
#: round-trip as a number (repr → 1e+308 → float).  Numeric consumers
#: (pandas, jq, the reporter) read it as "off the scale" instead of
#: choking on a string.
INF_CLAMP = 1e308


def jsonify(value):
    """Best-effort conversion of jax/numpy/py values to JSON-safe types.

    Non-finite floats stay *numeric-or-null* so downstream consumers never
    meet a surprise string in a number column: NaN → ``null`` (the JSON
    spelling of "no value"), ±inf → ``±1e308`` (clamped, still ordered
    correctly against every finite reading).  Shared by the telemetry
    writer and the ``BENCH_<name>.json`` benchmark artifacts."""
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        if np.isfinite(value):
            return value
        if np.isnan(value):
            return None
        return INF_CLAMP if value > 0 else -INF_CLAMP
    if isinstance(value, dict):
        return {str(k): jsonify(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [jsonify(v) for v in value]
    arr = np.asarray(value)
    if arr.ndim == 0:
        return jsonify(arr.item())
    return [jsonify(v) for v in arr.tolist()]


class TelemetryWriter:
    """Append-only JSONL sink; ``path=None`` makes every call a no-op."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self._f: Optional[IO[str]] = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._f = open(path, "a")

    @property
    def enabled(self) -> bool:
        return self._f is not None

    def log(self, kind: str, step: int, **metrics) -> None:
        """Write one record; jax arrays in ``metrics`` become lists."""
        if self._f is None:
            return
        rec = {"t": time.time(), "kind": kind, "step": int(step)}
        for k, v in metrics.items():
            rec[k] = jsonify(v)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TelemetryWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> list:
    """Load every record of a telemetry file (tests / offline analysis)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records
