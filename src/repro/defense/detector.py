"""Online Byzantine-count estimation and empirical Δ-resilience monitoring.

The paper's rules consume an a-priori bound on the Byzantine count (the b/q
parameters); its companion (Xie et al. 2018) frames the gap between the
assumed and the true count.  The detector closes that gap online:

  * :func:`estimate_q` reads q̂ off the *bimodality* of the per-worker
    suspicion scores: Byzantine workers cluster near 1, benign workers near
    their baseline, so the largest gap in the sorted score sequence splits
    the two modes.  A clean run has no decisive gap and q̂ = 0.

  * :func:`resilience_monitor` re-uses the paper's own theory
    (``core/bounds.py``) as a runtime invariant: estimate the benign
    variance V̂ from the low-suspicion rows, evaluate the rule's Δ bound at
    (m, q̂, b), and compare the aggregate's empirical squared deviation
    from the benign center against it.  A violated bound means the current
    attack has escaped the rule's resilience class (e.g. the
    inner-product-manipulation adversary of "Fall of Empires") — exactly
    the signal an adaptive aggregation policy needs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def estimate_q(scores: jax.Array, *, min_gap: float = 0.2) -> jax.Array:
    """Estimate the Byzantine count from score bimodality (jit-friendly).

    Sort suspicion descending; the largest inter-score gap in the top half
    splits the suspicious mode from the benign one, and q̂ = #workers above
    it.  Gaps below ``min_gap`` (no decisive bimodality) yield q̂ = 0.
    Only splits with q̂ <= m/2 are considered — more than m/2 Byzantine
    workers is outside every rule's resilience class, so a "majority
    suspicious" score vector reads as an uninformative signal, not a count.
    """
    m = scores.shape[0]
    s = -jnp.sort(-scores)                      # descending
    gaps = s[:-1] - s[1:]                       # gap after position i
    valid = jnp.arange(m - 1) < (m // 2)        # q_hat = i+1 <= m//2
    gaps = jnp.where(valid, gaps, -jnp.inf)
    i = jnp.argmax(gaps)
    return jnp.where(gaps[i] >= min_gap, i + 1, 0).astype(jnp.int32)


def _delta_bound(rule_name: str, m: int, q: int, b: int,
                 V: float) -> Optional[float]:
    """The paper's Δ bound for a rule at (m, q, b), or None when the theory
    has no bound for it (host-side helper; reuses ``core/bounds.py``)."""
    from repro.core import bounds
    try:
        if rule_name == "trmean":
            return bounds.delta_trmean(m, q, b, V)
        if rule_name in ("phocas", "mediam"):
            # mediam shares Phocas's dimensional class (looser constant);
            # the Phocas bound is the documented reference envelope.
            return bounds.delta_phocas(m, q, b, V)
        if rule_name in ("krum", "multikrum"):
            return bounds.delta_krum(m, q, V)
    except ValueError:
        return None      # assumption violated (2q >= m, b < q, ...)
    return None


def resilience_monitor(mat: jax.Array, agg: jax.Array, scores: jax.Array,
                       *, rule_name: str, b: int,
                       min_gap: float = 0.2) -> dict:
    """Empirical Δ-resilience check for one aggregation step (host-side).

    Args:
      mat: the (m, d) worker matrix the rule saw (post-attack).
      agg: the (d,) aggregate the rule produced.
      scores: (m,) suspicion under the ``defense.scores`` contract.

    Returns a dict with ``q_hat``, the benign-population variance estimate
    ``v_hat``, the empirical squared deviation of the aggregate from the
    benign center, the theoretical ``delta_bound`` at (m, q̂, b) (None when
    no bound applies), and ``within_bound``.
    """
    m = mat.shape[0]
    q_hat = int(estimate_q(scores, min_gap=min_gap))
    # Presumed-benign population: everything below the detector's split.
    order = jnp.argsort(-scores)
    benign_idx = order[q_hat:]
    benign = mat[benign_idx]
    center = jnp.mean(benign, axis=0)
    # V̂: total (over dimensions) per-worker variance around the benign mean
    # — the V of Definition 5 / Theorems 1-2.
    v_hat = float(jnp.mean(jnp.sum((benign - center[None]) ** 2, axis=1)))
    sq_dev = float(jnp.sum((agg - center) ** 2))
    bound = _delta_bound(rule_name, m, q_hat, b, v_hat)
    return {
        "q_hat": q_hat,
        "v_hat": v_hat,
        "sq_dev": sq_dev,
        "delta_bound": bound,
        "within_bound": (sq_dev <= bound) if bound is not None else None,
    }
