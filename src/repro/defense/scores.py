"""Per-worker suspicion scores — the defense subsystem's common currency.

Every registered rule already computes a per-worker signal internally and
(before this subsystem) threw it away every step: which values the trim
step of trmean/phocas dropped, the Krum pairwise-distance sums, the
Weiszfeld inverse-distance weights.  The ``reduce_with_scores`` /
``reduce_sharded_with_scores`` hooks on ``registry.AggregatorRule``
surface that signal under the **score contract**:

  * scores have shape ``(m,)``, live in ``[0, 1]``;
  * ``0`` = conforming (indistinguishable from the benign population),
    ``1`` = maximally suspicious;
  * in the sharded layouts the raw statistics are psum'd over the
    dimension-sharded worker axes + model axes BEFORE normalization, so
    every device holds identical global scores (same contract as the Krum
    partial-distance psums, DESIGN.md §6/§7).

The normalizers implementing the contract live in ``repro.core.registry``
(rules are in the core layer and must not import upward into
``repro.defense``); this module re-exports them as the defense-facing
names so consumers of scores never touch the registry internals.  Callers
obtain scores through ``aggregate_matrix(..., with_scores=True)`` /
``robust_aggregate_dist(..., with_scores=True)`` or directly via the rule
hooks.
"""
from repro.core.registry import (  # noqa: F401
    distance_ratio_scores, drop_frequency_scores,
)
