"""EMA worker reputation: turn per-step suspicion into a persistent trust
state with hysteresis ejection/readmission.

Per-step scores are noisy (one bad minibatch can make an honest worker look
briefly suspicious); an adaptive adversary can also behave for a while to
build trust ("Fall of Empires"-style).  The reputation state is the EMA

    rep_t = decay * rep_{t-1} + (1 - decay) * (1 - score_t)

with ``rep = 1`` fully trusted.  Ejection/readmission is a hysteresis gate:
a worker is ejected when its reputation falls below ``eject_below`` and
readmitted only after recovering above ``readmit_above`` (> eject_below),
so a worker oscillating near the threshold does not flap in and out of the
aggregation every step.  Ejected workers keep being scored (the rule sees
the full m-row matrix), so transiently-faulty workers earn their way back.

The state is a plain dict-of-arrays pytree — it threads through jitted
train steps (vmap and sharded layouts), checkpoints via
``repro.checkpoint.io`` unchanged, and is replicated across the mesh (it is
O(m), tiny).  The aggregation-side gate (replacing ejected rows before the
rule runs) lives in ``core/robust.py``; this module owns the state
dynamics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DefenseConfig:
    """Serializable spec of the online defense (CLI: --defense ...)."""
    reputation_decay: float = 0.9     # EMA decay toward the previous state
    eject_below: float = 0.5          # eject when reputation falls below
    readmit_above: float = 0.7        # readmit only after recovering above
    warmup_steps: int = 2             # no ejection before this many updates
    detector_min_gap: float = 0.2     # q-hat bimodality gap threshold
    telemetry_path: Optional[str] = None  # JSONL sink (None = off)
    # Adaptive rule parameters (ROADMAP item a): when True the experiment
    # step feeds the detector's online q̂ back into the rule — an
    # under-provisioned b/q is raised to q̂ (host-side re-jit) once the
    # detector reports q̂ > b for ``adapt_patience`` consecutive steps.
    # Opt-in: changing b changes the rule's static selection windows, so
    # each adaptation recompiles the train step.
    adapt_b: bool = False
    adapt_patience: int = 2           # consecutive q̂ > b steps before adapting

    def __post_init__(self):
        if not 0.0 < self.reputation_decay < 1.0:
            raise ValueError(f"reputation_decay must be in (0, 1), got "
                             f"{self.reputation_decay}")
        if self.readmit_above < self.eject_below:
            raise ValueError("readmit_above must be >= eject_below "
                             "(hysteresis band)")
        if self.adapt_patience < 1:
            raise ValueError("adapt_patience must be >= 1, got "
                             f"{self.adapt_patience}")


def init_reputation(m: int) -> dict:
    """Fresh reputation state for m workers (all trusted, all active)."""
    return {
        "reputation": jnp.ones((m,), jnp.float32),
        "active": jnp.ones((m,), jnp.float32),   # 1 = in the aggregation
        "steps": jnp.zeros((), jnp.int32),
    }


def update_reputation(state: dict, scores: jax.Array,
                      cfg: DefenseConfig) -> dict:
    """One EMA + hysteresis update from per-step suspicion ``scores``
    (shape (m,), in [0, 1] — the ``repro.defense.scores`` contract).
    Pure and jit-friendly; called inside the train step."""
    d = cfg.reputation_decay
    rep = d * state["reputation"] + (1.0 - d) * (1.0 - scores)
    steps = state["steps"] + 1
    can_eject = (steps > cfg.warmup_steps).astype(jnp.float32)
    active = state["active"]
    ejected = (rep < cfg.eject_below).astype(jnp.float32) * can_eject
    readmitted = (rep >= cfg.readmit_above).astype(jnp.float32)
    active = jnp.clip(active * (1.0 - ejected) + readmitted, 0.0, 1.0)
    return {"reputation": rep, "active": active, "steps": steps}


def suspicion_of(state: dict) -> jax.Array:
    """The smoothed suspicion view of the state (1 - reputation)."""
    return 1.0 - state["reputation"]
