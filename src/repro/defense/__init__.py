"""``repro.defense`` — online Byzantine detection, worker reputation, and
adaptive aggregation (DESIGN.md §7).

The aggregation rules' internal statistics (trim masks, Krum distances,
Weiszfeld weights) are a per-worker suspicion signal the reproduction used
to discard every step.  This subsystem turns them into a closed loop:

  ``scores``     — the per-worker suspicion contract + normalizers behind
                   every rule's ``reduce_with_scores`` hook;
  ``reputation`` — EMA trust state with hysteresis ejection/readmission,
                   threaded through the jitted train steps and checkpoints;
  ``detector``   — online q̂ estimation from score bimodality + an
                   empirical Δ-resilience monitor reusing ``core/bounds``;
  ``telemetry``  — structured per-step JSONL metrics shared by the sync,
                   async, streaming, and serving paths.
"""
from repro.defense.detector import estimate_q, resilience_monitor  # noqa: F401
from repro.defense.reputation import (  # noqa: F401
    DefenseConfig, init_reputation, suspicion_of, update_reputation,
)
from repro.defense.scores import (  # noqa: F401
    distance_ratio_scores, drop_frequency_scores,
)
from repro.defense.telemetry import TelemetryWriter, read_jsonl  # noqa: F401
