"""Pallas TPU kernel: fused Phocas aggregation.

Single VMEM pass per (m, TILE_D) block: computes the b-trimmed mean (as in
the trmean kernel), then drops the b values farthest from it by b masked
max-extractions on |u - t| and averages the remaining m-b — the trimmed mean
never round-trips to HBM, which is the fusion win over running trmean + a
second distance/selection pass (2 fewer HBM reads of the m×d matrix).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (DEFAULT_TILE_D, INTERPRET, extract_max,
                                  extract_min, pad_lanes)


def _phocas_kernel(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)              # (m, TILE_D)
    total = jnp.sum(u, axis=0)
    # --- trimmed mean (fused) ---
    tm_total = total
    valid = jnp.ones(u.shape, jnp.bool_)
    for _ in range(b):
        valid, tm_total, _ = extract_min(u, valid, tm_total)
    for _ in range(b):
        valid, tm_total, _ = extract_max(u, valid, tm_total)
    center = tm_total / (m - 2 * b)                 # (TILE_D,)
    # --- drop the b farthest-from-center values ---
    dist = jnp.abs(u - center[None])
    keep_total = total
    iota = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    for _ in range(b):
        mx = jnp.max(dist, axis=0)
        # Tie-break on the HIGHEST worker index, matching the stable-argsort
        # oracle (which ranks lower indices as "nearer" on equal distance).
        idx = jnp.max(jnp.where(dist == mx[None], iota, -1), axis=0)
        onehot = iota == idx[None]
        dropped = jnp.sum(jnp.where(onehot, u, 0.0), axis=0)
        keep_total = keep_total - dropped
        dist = jnp.where(onehot, -jnp.inf, dist)
    o_ref[...] = (keep_total / (m - b))[None]


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def phocas_pallas(u: jax.Array, b: int, *, tile_d: int = DEFAULT_TILE_D,
                  interpret: bool = INTERPRET) -> jax.Array:
    """(m, d) f32 -> (d,) Phocas aggregation via pallas_call."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    out = pl.pallas_call(
        functools.partial(_phocas_kernel, b=b, m=m),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(u)
    return out[0, :d]
