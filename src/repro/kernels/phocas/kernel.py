"""Pallas TPU kernels: fused Phocas aggregation.

Single VMEM pass per (m, TILE_D) block: computes the b-trimmed mean (as in
the trmean kernel), then drops the b values farthest from it and averages
the remaining m-b — the trimmed mean never round-trips to HBM, which is the
fusion win over running trmean + a second distance/selection pass (2 fewer
HBM reads of the m×d matrix).

Two variants share the public entry points (DESIGN.md §8):

* **extraction** (small b): b masked max-extractions on |u - t| along the
  sublane axis, tie-broken on the HIGHEST worker index to match the
  stable-argsort oracle — O(3b) unrolled passes in total.
* **network** (large b): one Batcher sorting network along the sublane axis
  (``core/selection.py``); the kept (m-b)-nearest set is a contiguous
  window of the sorted order, so the selection reduces to b+1 statically
  sliced candidate windows over a prefix sum — O(log²m) stages + O(b)
  cheap window ops.

The ``*_counts`` kernel additionally emits per-worker drop counts (the
defense suspicion statistic) as a second per-grid-block output, with padded
lanes masked out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.selection import (nearest_window_sum, sorted_rows,
                                  stable_ranks, trimmed_mean_of_sorted)
from repro.kernels.common import (DEFAULT_TILE_D, INTERPRET, extract_max,
                                  extract_min, pad_lanes)
from repro.kernels.trmean.kernel import (COUNTS_LANES, _counts_row,
                                         _lane_mask, _rows_of, use_network)


def _trimmed_center(u, *, b: int, m: int):
    """(total, trimmed-mean center) of an (m, TILE_D) block."""
    total = jnp.sum(u, axis=0)
    tm_total = total
    valid = jnp.ones(u.shape, jnp.bool_)
    for _ in range(b):
        valid, tm_total, _ = extract_min(u, valid, tm_total)
    for _ in range(b):
        valid, tm_total, _ = extract_max(u, valid, tm_total)
    return total, tm_total / (m - 2 * b)


def _drop_farthest(u, center, total, *, b: int):
    """Remove the b values farthest from ``center`` from ``total``.

    Ties break on the HIGHEST worker index, matching the stable-argsort
    oracle (which ranks lower indices as "nearer" on equal distance).
    Returns (kept total, (m, TILE_D) dropped mask).
    """
    dist = jnp.abs(u - center[None])
    iota = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    dropped = jnp.zeros(u.shape, jnp.bool_)
    for _ in range(b):
        mx = jnp.max(dist, axis=0)
        idx = jnp.max(jnp.where(dist == mx[None], iota, -1), axis=0)
        onehot = iota == idx[None]
        total = total - jnp.sum(jnp.where(onehot, u, 0.0), axis=0)
        dist = jnp.where(onehot, -jnp.inf, dist)
        dropped = dropped | onehot
    return total, dropped


def _phocas_kernel(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)              # (m, TILE_D)
    total, center = _trimmed_center(u, b=b, m=m)
    keep_total, _ = _drop_farthest(u, center, total, b=b)
    o_ref[...] = (keep_total / (m - b))[None]


def _phocas_kernel_net(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)
    srows = sorted_rows(_rows_of(u, m))
    center = trimmed_mean_of_sorted(srows, b)
    total, _ = nearest_window_sum(srows, center, b)
    o_ref[...] = (total / (m - b))[None]


def _phocas_counts_kernel(u_ref, o_ref, c_ref, *, b: int, m: int, d: int,
                          tile_d: int, network: bool):
    u = u_ref[...].astype(jnp.float32)
    lane_ok = _lane_mask(u.shape, block=pl.program_id(0), tile_d=tile_d, d=d)
    if network:
        rows = _rows_of(u, m)
        srows = sorted_rows(rows)
        center = trimmed_mean_of_sorted(srows, b)
        total, _ = nearest_window_sum(srows, center, b)
        ranks = stable_ranks([jnp.abs(r - center) for r in rows])
        dropped = jnp.stack([r >= m - b for r in ranks])
    else:
        total, center = _trimmed_center(u, b=b, m=m)
        total, dropped = _drop_farthest(u, center, total, b=b)
    o_ref[...] = (total / (m - b))[None]
    c_ref[...] = _counts_row(dropped, lane_ok, m)


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def phocas_pallas(u: jax.Array, b: int, *, tile_d: int = DEFAULT_TILE_D,
                  interpret: bool = INTERPRET) -> jax.Array:
    """(m, d) f32 -> (d,) Phocas aggregation via pallas_call."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    body = _phocas_kernel_net if use_network(m, 3 * b) else _phocas_kernel
    out = pl.pallas_call(
        functools.partial(body, b=b, m=m),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(u)
    return out[0, :d]


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def phocas_counts_pallas(u: jax.Array, b: int, *,
                         tile_d: int = DEFAULT_TILE_D,
                         interpret: bool = INTERPRET):
    """(m, d) f32 -> ((d,) Phocas aggregate, (m,) per-worker drop counts)."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    if m > COUNTS_LANES:
        raise ValueError(f"counts kernel packs m into {COUNTS_LANES} lanes; "
                         f"got m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    nblocks = dp // tile_d
    agg, counts = pl.pallas_call(
        functools.partial(_phocas_counts_kernel, b=b, m=m, d=d,
                          tile_d=tile_d, network=use_network(m, 3 * b)),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile_d), lambda i: (0, i)),
                   pl.BlockSpec((1, COUNTS_LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((nblocks, COUNTS_LANES),
                                        jnp.float32)],
        interpret=interpret,
    )(u)
    return agg[0, :d], jnp.sum(counts, axis=0)[:m]
