"""Jit'd public wrapper for the fused Phocas kernel."""
from __future__ import annotations

import jax

from repro.kernels.phocas.kernel import phocas_pallas
from repro.kernels.phocas.ref import phocas_ref


def phocas(u: jax.Array, b: int, *, use_kernel: bool = True) -> jax.Array:
    """Phocas aggregation; (m, d) -> (d,)."""
    if b == 0 or not use_kernel:
        return phocas_ref(u, b)
    return phocas_pallas(u, b)
