"""Jit'd public wrappers for the fused Phocas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.phocas.kernel import phocas_counts_pallas, phocas_pallas
from repro.kernels.phocas.ref import phocas_ref


def phocas(u: jax.Array, b: int, *, use_kernel: bool = True) -> jax.Array:
    """Phocas aggregation; (m, d) -> (d,)."""
    if b == 0 or not use_kernel:
        return phocas_ref(u, b)
    return phocas_pallas(u, b)


def phocas_with_counts(u: jax.Array, b: int):
    """Phocas aggregate AND per-worker drop counts; (m, d) -> ((d,), (m,)).

    The second output is the defense suspicion statistic (DESIGN.md §7/§8):
    how many coordinates dropped worker i as one of the b farthest from the
    center.  Backed by the score-emitting kernel so ``emits_scores`` no
    longer forces the XLA fallback.
    """
    if b == 0:
        return u.astype(jnp.float32).mean(axis=0), \
            jnp.zeros((u.shape[0],), jnp.float32)
    return phocas_counts_pallas(u, b)
