"""Pure-jnp oracle for Phocas (Definition 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.trmean.ref import trmean_ref


def phocas_ref(u: jax.Array, b: int) -> jax.Array:
    """(m, d) -> (d,): mean of the (m-b) values nearest to the b-trimmed mean."""
    m = u.shape[0]
    uf = u.astype(jnp.float32)
    center = trmean_ref(uf, b)
    if b == 0:
        return jnp.mean(uf, axis=0)
    dist = jnp.abs(uf - center[None])
    order = jnp.argsort(dist, axis=0)
    ranks = jnp.argsort(order, axis=0)
    keep = (ranks < (m - b)).astype(uf.dtype)
    return jnp.sum(uf * keep, axis=0) / (m - b)
