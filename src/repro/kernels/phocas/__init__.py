from repro.kernels.phocas.ops import phocas  # noqa: F401
