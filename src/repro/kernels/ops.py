"""Facade over the per-kernel ops modules.

The registered rules (``repro.core.aggregators``) reach these through their
``_reduce_pallas`` implementations when ``RobustConfig.backend`` resolves to
``"pallas"``; the facade remains for direct kernel benchmarking."""
from repro.kernels.trmean.ops import trmean, trmean_with_counts  # noqa: F401
from repro.kernels.phocas.ops import phocas, phocas_with_counts  # noqa: F401
from repro.kernels.krum.ops import krum, multikrum, pairwise_sq_dists  # noqa: F401
