"""Facade over the per-kernel ops modules (used by RobustConfig.use_kernels)."""
from repro.kernels.trmean.ops import trmean  # noqa: F401
from repro.kernels.phocas.ops import phocas  # noqa: F401
from repro.kernels.krum.ops import krum, multikrum, pairwise_sq_dists  # noqa: F401
