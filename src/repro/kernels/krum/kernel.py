"""Pallas TPU kernel: Krum pairwise squared distances, d-tiled Gram accumulation.

Krum's O(m²d) cost is dominated by the pairwise-distance pass, which maps
onto the MXU as a Gram matrix: per (m, TILE_D) block compute
``G += U·Uᵀ`` (128-aligned contraction) and the row square-norms, then the
epilogue assembles ``d²(i,j) = n_i + n_j - 2·G_ij`` after the grid finishes.
The (m, m) accumulator lives in the output VMEM block across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import DEFAULT_TILE_D, INTERPRET, pad_lanes


def _gram_kernel(u_ref, gram_ref, norms_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        norms_ref[...] = jnp.zeros_like(norms_ref)

    u = u_ref[...].astype(jnp.float32)                     # (m, TILE_D)
    gram_ref[...] += jax.lax.dot_general(
        u, u, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (m, m) on the MXU
    norms_ref[...] += jnp.sum(u * u, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def pairwise_sq_dists_pallas(u: jax.Array, *, tile_d: int = DEFAULT_TILE_D,
                             interpret: bool = INTERPRET) -> jax.Array:
    """(m, d) -> (m, m) squared distances via d-tiled MXU Gram accumulation."""
    m = u.shape[0]
    u = u.astype(jnp.float32)
    u, _ = pad_lanes(u, tile_d)
    dp = u.shape[1]
    gram, norms = pl.pallas_call(
        _gram_kernel,
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((m, m), lambda i: (0, 0)),
                   pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, m), jnp.float32),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(u)
    n = norms[:, 0]
    return jnp.maximum(n[:, None] + n[None, :] - 2.0 * gram, 0.0)
