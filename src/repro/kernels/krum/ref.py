"""Pure-jnp oracle for the Krum pairwise-distance matrix."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dists_ref(u: jax.Array) -> jax.Array:
    """(m, d) -> (m, m) squared Euclidean distances (direct, no Gram trick)."""
    uf = u.astype(jnp.float32)
    diff = uf[:, None, :] - uf[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
