"""Jit'd wrappers: kernel-backed Krum / Multi-Krum."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.krum.kernel import pairwise_sq_dists_pallas
from repro.kernels.krum.ref import pairwise_sq_dists_ref


def pairwise_sq_dists(u: jax.Array, *, use_kernel: bool = True) -> jax.Array:
    if not use_kernel:
        return pairwise_sq_dists_ref(u)
    return pairwise_sq_dists_pallas(u)


def _scores(u: jax.Array, q: int, use_kernel: bool) -> jax.Array:
    m = u.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    d2 = pairwise_sq_dists(u, use_kernel=use_kernel)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    return jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)


def krum(u: jax.Array, q: int, *, use_kernel: bool = True) -> jax.Array:
    """(m, d) -> (d,): the candidate with minimal Krum score (Definition 3)."""
    return u[jnp.argmin(_scores(u, q, use_kernel))].astype(jnp.float32)


def multikrum(u: jax.Array, q: int, k: int | None = None, *,
              use_kernel: bool = True) -> jax.Array:
    """(m, d) -> (d,): mean of the k lowest-score candidates."""
    m = u.shape[0]
    if k is None:
        k = m - q - 2
    scores = _scores(u, q, use_kernel)
    _, idx = jax.lax.top_k(-scores, k)
    return jnp.mean(u.astype(jnp.float32)[idx], axis=0)
