from repro.kernels.krum.ops import pairwise_sq_dists, krum, multikrum  # noqa: F401
