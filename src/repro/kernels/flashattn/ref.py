"""Pure-jnp oracle for the flash-attention kernel (naive full-score path)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        cap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,Kv,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, S, Kv, rep, hd)
    s = jnp.einsum("bqkrh,btkh->bkrqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    q_pos = jnp.arange(S)
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos[None] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bkrqt,btkh->bqkrh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)
