from repro.kernels.flashattn.ops import flash_attention  # noqa: F401
