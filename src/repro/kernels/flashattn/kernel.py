"""Fused flash attention (Pallas, TPU target) — §Perf P5.

Motivation from the roofline log: the chunked-XLA attention materializes f32
score/softmax tensors in HBM (the dominant slice of the memory term on every
train/prefill pair).  The fused kernel keeps the (bq, bk) score tile in VMEM
with an online-softmax accumulator, so per-layer attention HBM traffic drops
from O(S·T·4B) (f32 scores read+written) to O(S·hd + T·hd) operand traffic —
for gemma3 train_4k that is ~99% of the score-buffer bytes removed.

Grid: (B·H, nq, nk) with the k axis innermost/sequential; the output tile is
revisited across nk steps with running (max, denom, acc) scratch in VMEM.
GQA maps query head bh -> kv head via the BlockSpec index_map, sliding-window
+ causal masking via block-position iota, optional logit softcap (gemma2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, cap, bq, bk, nk):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    correction = jnp.exp(m_prev - m_new)           # (bq, 1)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "scale", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           cap: Optional[float] = None,
                           scale: Optional[float] = None,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = INTERPRET) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,Kv,hd) -> (B,S,H,hd).  S % bq == T % bk == 0
    (the ops wrapper pads)."""
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    if scale is None:
        scale = hd ** -0.5
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0
    nq, nk = S // bq, T // bk

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, T, hd)

    def kv_map(bh, iq_, ik_):
        return (bh // rep, ik_, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, cap=cap, bq=bq, bk=bk, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq_, ik_: (bh, iq_, 0)),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq_, ik_: (bh, iq_, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
