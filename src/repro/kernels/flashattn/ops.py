"""Jit'd wrapper for the flash-attention kernel: pads S/T to block multiples
and dispatches kernel vs oracle."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flashattn.kernel import (DEFAULT_BK, DEFAULT_BQ,
                                            flash_attention_pallas)
from repro.kernels.flashattn.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    use_kernel: bool = True) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,Kv,hd) -> (B,S,H,hd)."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   cap=cap, scale=scale)
    B, S, H, hd = q.shape
    T = k.shape[1]
    bq_, bk_ = min(bq, S), min(bk, T)
    pad_q = (-S) % bq_
    pad_k = (-T) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        # padded keys land at positions > any query -> masked out by causal;
        # for non-causal we mask via window... guard: require causal or
        # no padding.
        assert causal, "non-causal padding unsupported; pick divisible bk"
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 cap=cap, scale=scale, bq=bq_, bk=bk_)
    return out[:, :S]
