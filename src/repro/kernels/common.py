"""Shared helpers for the aggregation Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Lane-axis tile: multiple of 128 (TPU lane width).  With m <= 64 workers on
# the sublane axis, an (m, 2048) f32 block is m*8KB <= 512KB — comfortably
# inside the ~16MB VMEM budget even with double buffering.
DEFAULT_TILE_D = 2048

# Sublane (second-minor) axis of the f32 TPU vector-memory tile: min tile is
# (8, 128).  Layout constants that put a token/worker axis on the sublane
# dimension (e.g. serve/cache.DEFAULT_BLOCK_TOKENS) must be multiples of it.
SUBLANE = 8

# On CPU containers Pallas runs the kernel body in interpret mode.
INTERPRET = jax.default_backend() == "cpu"


def extract_min(u: jax.Array, valid: jax.Array, total: jax.Array):
    """Remove one occurrence of the per-column minimum over the still-valid
    entries from the running sum.

    Returns (updated valid mask, updated total, removed values).
    u: (m, t) values (never mutated), valid: (m, t) bool, total: (t,).
    """
    masked = jnp.where(valid, u, jnp.inf)
    idx = jnp.argmin(masked, axis=0)                  # (t,)
    onehot = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0) == idx[None]
    vals = jnp.sum(jnp.where(onehot, u, 0.0), axis=0)
    return valid & ~onehot, total - vals, vals


def extract_max(u: jax.Array, valid: jax.Array, total: jax.Array):
    """Mirror of :func:`extract_min` for the per-column maximum."""
    masked = jnp.where(valid, u, -jnp.inf)
    idx = jnp.argmax(masked, axis=0)
    onehot = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0) == idx[None]
    vals = jnp.sum(jnp.where(onehot, u, 0.0), axis=0)
    return valid & ~onehot, total - vals, vals


def extract_max_stable(u: jax.Array, valid: jax.Array, total: jax.Array):
    """:func:`extract_max` with ties broken on the HIGHEST worker index.

    ``argmax`` prefers the lowest index; the stable-argsort oracle ranks
    equal values by index ascending, so the *largest* (value, index) pair —
    the one a stable trim drops first — is the highest-indexed tie.  The
    aggregate can't tell (equal values sum equally) but the per-worker drop
    masks the score kernels emit can, so they must extract with this
    variant to match the XLA stable-rank counts bit-for-bit.
    """
    masked = jnp.where(valid, u, -jnp.inf)
    iota = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    mx = jnp.max(masked, axis=0)
    idx = jnp.max(jnp.where(masked == mx[None], iota, -1), axis=0)
    onehot = iota == idx[None]
    vals = jnp.sum(jnp.where(onehot, u, 0.0), axis=0)
    return valid & ~onehot, total - vals, vals


def pad_lanes(u: jax.Array, tile: int):
    """Pad the lane (last) axis of (m, d) to a multiple of ``tile``."""
    d = u.shape[-1]
    pad = (-d) % tile
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad)))
    return u, d
