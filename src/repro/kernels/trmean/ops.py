"""Jit'd public wrapper for the trimmed-mean kernel."""
from __future__ import annotations

import jax

from repro.kernels.trmean.kernel import trmean_pallas
from repro.kernels.trmean.ref import trmean_ref


def trmean(u: jax.Array, b: int, *, use_kernel: bool = True) -> jax.Array:
    """Coordinate-wise b-trimmed mean; (m, d) -> (d,).

    ``use_kernel=False`` falls back to the jnp oracle (used for leaves too
    small to be worth a pallas_call, and in tests as the reference).
    """
    if b == 0 or not use_kernel:
        return trmean_ref(u, b) if b else u.mean(axis=0)
    return trmean_pallas(u, b)
