"""Jit'd public wrappers for the trimmed-mean kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.trmean.kernel import trmean_counts_pallas, trmean_pallas
from repro.kernels.trmean.ref import trmean_ref


def trmean(u: jax.Array, b: int, *, use_kernel: bool = True) -> jax.Array:
    """Coordinate-wise b-trimmed mean; (m, d) -> (d,).

    ``use_kernel=False`` falls back to the jnp oracle (used for leaves too
    small to be worth a pallas_call, and in tests as the reference).
    """
    if b == 0 or not use_kernel:
        return trmean_ref(u, b) if b else u.mean(axis=0)
    return trmean_pallas(u, b)


def trmean_with_counts(u: jax.Array, b: int):
    """Trimmed mean AND per-worker drop counts; (m, d) -> ((d,), (m,)).

    The second output is the defense suspicion statistic (DESIGN.md §7/§8):
    how many coordinates trimmed worker i away.  Backed by the score-
    emitting kernel so ``emits_scores`` no longer forces the XLA fallback.
    """
    if b == 0:
        return u.mean(axis=0), jnp.zeros((u.shape[0],), jnp.float32)
    return trmean_counts_pallas(u, b)
