"""Pallas TPU kernel: coordinate-wise b-trimmed mean over m workers.

TPU adaptation of the paper's selection-algorithm aggregation (§4.4): instead
of a serial selection/sort, each (m, TILE_D) VMEM block removes its b smallest
and b largest values per column by b unrolled masked min/max extractions along
the sublane (worker) axis — O(b·m·TILE_D) vectorized work, everything VMEM
resident, d on the 128-wide lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (DEFAULT_TILE_D, INTERPRET, extract_max,
                                  extract_min, pad_lanes)


def _trmean_kernel(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)          # (m, TILE_D)
    total = jnp.sum(u, axis=0)                  # (TILE_D,)
    valid = jnp.ones(u.shape, jnp.bool_)
    for _ in range(b):                          # b static & small: unrolled
        valid, total, _ = extract_min(u, valid, total)
    for _ in range(b):
        valid, total, _ = extract_max(u, valid, total)
    o_ref[...] = (total / (m - 2 * b))[None]


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def trmean_pallas(u: jax.Array, b: int, *, tile_d: int = DEFAULT_TILE_D,
                  interpret: bool = INTERPRET) -> jax.Array:
    """(m, d) f32 -> (d,) b-trimmed mean via pallas_call."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    out = pl.pallas_call(
        functools.partial(_trmean_kernel, b=b, m=m),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(u)
    return out[0, :d]
