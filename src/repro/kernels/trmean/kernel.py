"""Pallas TPU kernels: coordinate-wise b-trimmed mean over m workers.

TPU adaptation of the paper's selection-algorithm aggregation (§4.4).  Two
variants share the public entry points (DESIGN.md §8):

* **extraction** (small b): each (m, TILE_D) VMEM block removes its b
  smallest and b largest values per column by 2b unrolled masked min/max
  extractions along the sublane (worker) axis — O(b·m·TILE_D) vectorized
  work, everything VMEM resident, d on the 128-wide lane axis.
* **network** (large b): a Batcher odd-even merge sorting network along the
  sublane axis (``core/selection.py``), O(log²m) compare-exchange stages of
  O(m·TILE_D) vector work each, after which every trim window is a static
  row slice.  Chosen when the 2b extraction passes would cost more than the
  network's stages.

The ``*_counts`` kernels additionally emit per-worker drop counts — the
defense suspicion statistic — as a second output accumulated per grid
block, so ``emits_scores`` no longer forces the XLA fallback on TPU.
Padded lanes are masked out of the counts; tie handling matches the XLA
stable-rank masks exactly (stable extraction / ``stable_ranks``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.selection import (network_stages, sorted_rows, stable_ranks,
                                  trimmed_mean_of_sorted)
from repro.kernels.common import (DEFAULT_TILE_D, INTERPRET, extract_max,
                                  extract_max_stable, extract_min, pad_lanes)

# Score kernels pack per-worker counts into one 128-lane output row.
COUNTS_LANES = 128


def use_network(m: int, passes: int) -> bool:
    """Variant heuristic: an extraction pass and a network stage are both
    O(m·TILE_D) vector work, so the O(log²m)-stage network wins once the
    unrolled extraction loop needs more than that many passes.  Strictly
    more: at parity the extraction variant is preferred because its
    index-aware tie handling matches the stable-argsort oracle exactly,
    which the value-only network cannot (boundary distance ties)."""
    return passes > network_stages(m)


def _lane_mask(shape, *, block: int, tile_d: int, d: int):
    """(… , TILE_D) bool mask of lanes holding real (un-padded) columns."""
    lane = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    return block * tile_d + lane < d


def _counts_row(dropped, lane_ok, m: int):
    """Sum an (m, TILE_D) drop mask over valid lanes into a (1, 128) row."""
    counts = jnp.sum(jnp.where(dropped & lane_ok, 1.0, 0.0), axis=1)
    return jnp.pad(counts, (0, COUNTS_LANES - m))[None]


def _trmean_kernel(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)          # (m, TILE_D)
    total = jnp.sum(u, axis=0)                  # (TILE_D,)
    valid = jnp.ones(u.shape, jnp.bool_)
    for _ in range(b):                          # b static & small: unrolled
        valid, total, _ = extract_min(u, valid, total)
    for _ in range(b):
        valid, total, _ = extract_max(u, valid, total)
    o_ref[...] = (total / (m - 2 * b))[None]


def _rows_of(u, m: int):
    """Worker rows with NaN mapped to +inf (the sort-last placement the
    XLA selection path uses, ``selection.worker_rows``)."""
    return [jnp.where(jnp.isnan(u[i]), jnp.inf, u[i]) for i in range(m)]


def _trmean_kernel_net(u_ref, o_ref, *, b: int, m: int):
    u = u_ref[...].astype(jnp.float32)
    srows = sorted_rows(_rows_of(u, m))
    o_ref[...] = trimmed_mean_of_sorted(srows, b)[None]


def _trmean_counts_kernel(u_ref, o_ref, c_ref, *, b: int, m: int, d: int,
                          tile_d: int, network: bool):
    u = u_ref[...].astype(jnp.float32)
    lane_ok = _lane_mask(u.shape, block=pl.program_id(0), tile_d=tile_d, d=d)
    if network:
        rows = _rows_of(u, m)
        srows = sorted_rows(rows)
        agg = trimmed_mean_of_sorted(srows, b)
        ranks = stable_ranks(rows)
        dropped = jnp.stack([(r < b) | (r >= m - b) for r in ranks])
    else:
        total = jnp.sum(u, axis=0)
        valid = jnp.ones(u.shape, jnp.bool_)
        for _ in range(b):
            valid, total, _ = extract_min(u, valid, total)
        for _ in range(b):
            valid, total, _ = extract_max_stable(u, valid, total)
        agg = total / (m - 2 * b)
        dropped = ~valid
    o_ref[...] = agg[None]
    c_ref[...] = _counts_row(dropped, lane_ok, m)


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def trmean_pallas(u: jax.Array, b: int, *, tile_d: int = DEFAULT_TILE_D,
                  interpret: bool = INTERPRET) -> jax.Array:
    """(m, d) f32 -> (d,) b-trimmed mean via pallas_call."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    body = _trmean_kernel_net if use_network(m, 2 * b) else _trmean_kernel
    out = pl.pallas_call(
        functools.partial(body, b=b, m=m),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(u)
    return out[0, :d]


@functools.partial(jax.jit, static_argnames=("b", "tile_d", "interpret"))
def trmean_counts_pallas(u: jax.Array, b: int, *,
                         tile_d: int = DEFAULT_TILE_D,
                         interpret: bool = INTERPRET):
    """(m, d) f32 -> ((d,) trimmed mean, (m,) per-worker drop counts)."""
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")
    if m > COUNTS_LANES:
        raise ValueError(f"counts kernel packs m into {COUNTS_LANES} lanes; "
                         f"got m={m}")
    u = u.astype(jnp.float32)
    u, d = pad_lanes(u, tile_d)
    dp = u.shape[1]
    nblocks = dp // tile_d
    agg, counts = pl.pallas_call(
        functools.partial(_trmean_counts_kernel, b=b, m=m, d=d,
                          tile_d=tile_d, network=use_network(m, 2 * b)),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((m, tile_d), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile_d), lambda i: (0, i)),
                   pl.BlockSpec((1, COUNTS_LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((nblocks, COUNTS_LANES),
                                        jnp.float32)],
        interpret=interpret,
    )(u)
    return agg[0, :d], jnp.sum(counts, axis=0)[:m]
