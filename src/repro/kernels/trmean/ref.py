"""Pure-jnp oracle for the coordinate-wise b-trimmed mean (Definition 7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trmean_ref(u: jax.Array, b: int) -> jax.Array:
    """(m, d) -> (d,): average of the middle m-2b order statistics per column."""
    m = u.shape[0]
    s = jnp.sort(u.astype(jnp.float32), axis=0)
    return jnp.mean(s[b : m - b], axis=0)
