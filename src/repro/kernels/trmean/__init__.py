from repro.kernels.trmean.ops import trmean  # noqa: F401
