"""Shared per-coordinate selection pass for the coordinate-wise rules.

Every coordinate-wise rule in the stack (median / trmean / phocas / mediam,
their ``*_stats`` score variants, and the defense gate's median row) is a
composition of the same two primitives over the worker axis:

* **order statistics** of the m values at each coordinate (centers, trim
  windows, the gate's median row), and
* **stable selection ranks** (which workers the trim/selection step drops —
  the defense suspicion signal).

Before this module each rule paid for those separately with full
``jnp.sort`` + double-``argsort`` rank tricks — up to three O(m log m)
XLA sorts per rule per step, and XLA's CPU sort lowers to a scalar
comparator loop that is dramatically slower than the fused vector code the
same backend emits for min/max/where.  This module computes each primitive
once, in a form XLA fuses well, and every rule reads the shared result:

* :func:`sorted_rows` — a Batcher odd-even merge sorting **network** over a
  Python list of ``(d,)`` rows.  Compare-exchanges are ``minimum``/
  ``maximum`` pairs on row vectors, so the whole network fuses into wide
  vector code with no (m, d) temporaries and no comparator calls
  (~100x faster than ``jnp.sort`` on the CPU backend at m=8).  Falls back
  to one ``jnp.sort`` above ``_NETWORK_MAX_M`` where O(m log^2 m) network
  traffic would lose.
* :func:`stable_ranks` — exact stable-argsort ranks via O(m^2) pairwise
  lexicographic ``(key, worker index)`` comparisons, again pure fused
  vector ops.  Reproduces ``argsort(argsort(key))`` bit-for-bit, including
  duplicate handling.  Falls back to the double-argsort above
  ``_PAIRWISE_MAX_M``.
* :func:`trim_family` — the one driver behind trmean/phocas/mediam (and
  their fused defense paths): one sorted block feeds the center, the
  selection window, the raw-submission drop ranks, the gate's median row,
  and the gated re-aggregation.

The Pallas kernels reuse :func:`sorted_rows` / :func:`stable_ranks` inside
their kernel bodies for the large-b variants (DESIGN.md §8).
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Above these worker counts the O(m^2) pairwise ranks / O(m log^2 m) network
# lose to XLA's O(m log m) sort despite its worse constant; both bounds are
# far beyond the paper's experiments (m <= 100).
_NETWORK_MAX_M = 128
_PAIRWISE_MAX_M = 64

# One-time-per-process warning guard for the stable_ranks fallback cliff
# (ROADMAP selection follow-up c): above _PAIRWISE_MAX_M the exact pairwise
# path would cost O(m^2) compares, so we route through the documented
# double-argsort fallback — semantically identical, but it re-pays the two
# XLA sorts the fused path exists to avoid.  Warn once so large-fleet users
# know the perf model changed instead of silently losing the speedup.
_RANK_FALLBACK_WARNED = False


def _warn_rank_fallback(m: int) -> None:
    global _RANK_FALLBACK_WARNED
    if _RANK_FALLBACK_WARNED:
        return
    _RANK_FALLBACK_WARNED = True
    warnings.warn(
        f"stable_ranks: m={m} exceeds _PAIRWISE_MAX_M={_PAIRWISE_MAX_M}; "
        "falling back to the double-argsort rank path (two O(m log m) XLA "
        "sorts per call — bit-identical results, but the fused O(m^2) "
        "pairwise speedup no longer applies at this fleet size). "
        "This warning is emitted once per process.",
        RuntimeWarning, stacklevel=3)


def _as_f32(u: jax.Array) -> jax.Array:
    return u.astype(jnp.float32) if u.dtype != jnp.float32 else u


def worker_rows(u: jax.Array) -> List[jax.Array]:
    """Split an (m, *shape) block into a list of m f32 rows.

    The list-of-rows form is what lets XLA fuse the selection math: every
    downstream op is elementwise over ``shape``-shaped vectors instead of
    materializing (m, *shape) temporaries.

    NaN submissions (the cheapest Byzantine payload) are mapped to +inf:
    ``jnp.sort`` placed NaN past every real value so the old paths trimmed
    it away, but the network's min/max compare-exchanges and the pairwise
    rank compares would both let NaN poison every coordinate instead of
    being selected against.  +inf reproduces the sort-last placement for
    the trim windows, the distance ranks, AND the suspicion scores.
    """
    uf = _as_f32(u)
    return [jnp.where(jnp.isnan(uf[i]), jnp.inf, uf[i])
            for i in range(u.shape[0])]


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@functools.lru_cache(maxsize=None)
def batcher_pairs(n: int) -> Tuple[Tuple[int, int], ...]:
    """Batcher odd-even mergesort compare-exchange schedule for n = 2^k."""
    if n & (n - 1):
        raise ValueError(f"batcher_pairs needs a power of two, got {n}")
    pairs = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(k):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return tuple(pairs)


def network_stages(m: int) -> int:
    """Stage count of the Batcher network on next_pow2(m) inputs —
    O(log^2 m), the unit the kernels' variant heuristic compares against
    masked-extraction pass counts."""
    k = max(1, next_pow2(m).bit_length() - 1)
    return k * (k + 1) // 2


def sorted_rows(rows: Sequence[jax.Array]) -> List[jax.Array]:
    """Sort m same-shaped rows coordinate-wise ascending; returns m rows.

    Values only (worker identity is not tracked — use :func:`stable_ranks`
    when the selection mask must name workers).  Non-power-of-two m is
    padded with +inf rows that sort past every real value.
    """
    m = len(rows)
    if m <= 1:
        return list(rows)
    if m > _NETWORK_MAX_M:
        s = jnp.sort(jnp.stack(rows), axis=0)
        return [s[i] for i in range(m)]
    mp = next_pow2(m)
    work = list(rows)
    if mp != m:
        inf = jnp.full_like(rows[0], jnp.inf)
        work += [inf] * (mp - m)
    for a, b in batcher_pairs(mp):
        lo = jnp.minimum(work[a], work[b])
        hi = jnp.maximum(work[a], work[b])
        work[a], work[b] = lo, hi
    return work[:m]


def stable_ranks(keys: Sequence[jax.Array]) -> List[jax.Array]:
    """Exact stable-argsort ranks of m rows: ``ranks[i]`` counts workers j
    with ``(key_j, j) < (key_i, i)`` lexicographically — identical to
    ``argsort(argsort(stack(keys), axis=0), axis=0)[i]`` for every input,
    duplicates included, but as O(m^2) fused vector compares instead of two
    XLA sorts."""
    m = len(keys)
    if m > _PAIRWISE_MAX_M:
        _warn_rank_fallback(m)
        stacked = jnp.stack(keys)
        r = jnp.argsort(jnp.argsort(stacked, axis=0), axis=0)
        return [r[i] for i in range(m)]
    ranks = []
    for i in range(m):
        r = jnp.zeros_like(keys[i], dtype=jnp.int32)
        for j in range(m):
            if j == i:
                continue
            lt = keys[j] < keys[i]
            if j < i:  # stable: equal keys rank by worker index
                lt = lt | (keys[j] == keys[i])
            r = r + lt.astype(jnp.int32)
        ranks.append(r)
    return ranks


def median_of_sorted(srows: Sequence[jax.Array]) -> jax.Array:
    """Coordinate-wise median from an already-sorted row list."""
    m = len(srows)
    if m % 2:
        return srows[m // 2]
    return 0.5 * (srows[m // 2 - 1] + srows[m // 2])


def trimmed_mean_of_sorted(srows: Sequence[jax.Array], b: int) -> jax.Array:
    """b-trimmed mean (Definition 7) from an already-sorted row list."""
    m = len(srows)
    kept = srows[b:m - b]
    return sum(kept[1:], start=kept[0]) / len(kept) if len(kept) > 1 \
        else kept[0]


def nearest_window_sum(srows: Sequence[jax.Array], center: jax.Array,
                       drop: int) -> Tuple[jax.Array, jax.Array]:
    """Sum of the (m - drop) values nearest ``center`` per coordinate.

    The nearest set is always a contiguous window of the sorted order, so
    only drop+1 candidate windows exist; each is scored by its worst
    distance and the best window's sum is read off a running prefix sum.
    Ties between candidate windows (values symmetric around the center)
    resolve to the leftmost window — the same boundary-tie class the
    Pallas kernels document vs the stable-argsort oracle.

    Returns ``(window_sum, window_start)``.
    """
    m = len(srows)
    k = m - drop
    if drop == 0:
        return sum(srows[1:], start=srows[0]), \
            jnp.zeros_like(center, dtype=jnp.int32)
    widths = [jnp.maximum(center - srows[j], srows[j + k - 1] - center)
              for j in range(drop + 1)]
    best, bestj = widths[0], jnp.zeros_like(center, dtype=jnp.int32)
    for j in range(1, drop + 1):
        better = widths[j] < best
        best = jnp.where(better, widths[j], best)
        bestj = jnp.where(better, j, bestj)
    # Masked accumulation over the sorted rows, NOT a prefix-sum
    # difference: a prefix that passes through an adversarial 1e20 row
    # would cancel catastrophically in f32 and erase the kept values.
    total = jnp.zeros_like(center)
    for p in range(m):
        keep = (bestj <= p) & (p < bestj + k)
        total = total + jnp.where(keep, srows[p], 0.0)
    return total, bestj


def ncoords_of(u: jax.Array) -> jax.Array:
    """Static count of coordinates per worker (trailing-shape product)."""
    return jnp.float32(math.prod(u.shape[1:]) or 1)


def _count_per_worker(drop_masks: Sequence[jax.Array]) -> jax.Array:
    return jnp.stack([jnp.sum(d, dtype=jnp.float32) for d in drop_masks])


def validate_b(m: int, b: int) -> None:
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")


# Center of each trim-family rule, as a function of the sorted block.
_CENTERS = {
    "trmean": trimmed_mean_of_sorted,          # Definition 7 center
    "phocas": trimmed_mean_of_sorted,          # Definition 8 center
    "mediam": lambda srows, b: median_of_sorted(srows),   # Xie et al. 2018
}


def trim_family(u: jax.Array, b: int, kind: str, *,
                active: Optional[jax.Array] = None,
                with_scores: bool = False):
    """One shared selection pass behind trmean / phocas / mediam.

    Computes, from a single sorted block of the raw (m, *shape) matrix:
    the rule's center, its aggregate, optionally the per-worker drop counts
    of the RAW submissions (the defense score statistic), and — when
    ``active`` is given — the reputation-gated aggregate, whose gate median
    row is free once the raw block is sorted (DESIGN.md §8).

    Returns ``(agg, drop_counts, ncoords)``; ``drop_counts`` is None unless
    ``with_scores``.  Score semantics are unchanged from the pre-fusion
    stack: counts observe the raw matrix even when the aggregate is gated.
    """
    if kind not in _CENTERS:
        raise ValueError(f"unknown trim-family rule kind {kind!r}")
    m = u.shape[0]
    validate_b(m, b)
    rows = worker_rows(u)
    counts = None
    if b == 0:
        # Every trim-family rule degenerates to the plain mean — but the
        # reputation gate still applies (an ejected row must not re-enter
        # the average).
        if with_scores:
            counts = jnp.zeros((m,), jnp.float32)
        if active is not None:
            med = median_of_sorted(sorted_rows(rows))
            rows = [jnp.where(active[i] > 0, rows[i], med)
                    for i in range(m)]
        agg = sum(rows[1:], start=rows[0]) / m
        return agg, counts, ncoords_of(u)

    srows = sorted_rows(rows)
    center = _CENTERS[kind](srows, b)

    if with_scores:
        if kind == "trmean":
            ranks = stable_ranks(rows)
            dropped = [(r < b) | (r >= m - b) for r in ranks]
        else:
            dists = [jnp.abs(r - center) for r in rows]
            ranks = stable_ranks(dists)
            dropped = [r >= m - b for r in ranks]
        counts = _count_per_worker(dropped)

    if active is not None:
        # Reputation gate: ejected rows -> the raw matrix's median row
        # (read straight off the sorted block), then re-sort and re-center.
        # The raw aggregate is never materialized — this is the fusion that
        # keeps a defense-enabled step from running the rule twice.
        med = median_of_sorted(srows)
        rows = [jnp.where(active[i] > 0, rows[i], med) for i in range(m)]
        srows = sorted_rows(rows)
        center = _CENTERS[kind](srows, b)

    if kind == "trmean":
        agg = trimmed_mean_of_sorted(srows, b)
    else:
        total, _ = nearest_window_sum(srows, center, b)
        agg = total / (m - b)
    return agg, counts, ncoords_of(u)


def matrix_median(u: jax.Array) -> jax.Array:
    """Coordinate-wise median of an (m, *shape) block via the network."""
    return median_of_sorted(sorted_rows(worker_rows(u)))


def gate_matrix(mat: jax.Array, active: jax.Array) -> jax.Array:
    """Replace ejected workers' rows before an aggregation rule runs.

    ``active`` is the (m,) 0/1 mask from the reputation state
    (``repro.defense.reputation``).  Ejected rows are replaced with the
    coordinate-wise median of the matrix — a dimensional-robust proxy that
    is exact slice-locally in both collective layouts, so the gate composes
    with ``shard_map`` without extra collectives.  The rule still sees m
    rows (its b/q parameters keep their meaning) but an ejected worker's
    values can no longer move any order statistic beyond the median.

    A *concrete* all-ones mask (no ejections, outside jit) short-circuits
    to the input — the gate costs nothing until a worker is ejected.
    """
    if not isinstance(active, jax.core.Tracer):
        import numpy as np
        if bool(np.all(np.asarray(active) > 0)):
            return mat
    med = matrix_median(mat)
    keep = active.reshape((mat.shape[0],) + (1,) * (mat.ndim - 1))
    return jnp.where(keep > 0, mat, med[None].astype(mat.dtype))
