"""Core library: the paper's contribution (robust aggregation) as composable
JAX modules, dispatched through the pluggable Rule/Attack registry."""
from repro.core import registry, selection  # noqa: F401
from repro.core.registry import (  # noqa: F401
    AggregatorRule, RuleParams, register_rule, register_attack,
    available_rules, available_attacks, make_rule,
)
from repro.core.aggregators import (  # noqa: F401
    mean, median, trmean, phocas, krum, multikrum, geomedian, krum_scores,
    get_aggregator, COORDINATE_WISE, VECTOR_WISE,
)
from repro.core.attacks import AttackConfig, make_attack  # noqa: F401
from repro.core import rules  # noqa: F401  (single-file rule plugins)
from repro.core.robust import (  # noqa: F401
    RobustConfig, aggregate_matrix, aggregate_stacked_tree, gate_matrix,
    robust_aggregate_dist,
)
from repro.core import bounds  # noqa: F401
