"""Byzantine attack suite (paper §5 + beyond-paper extensions).

Every attack is a pure function ``(key, u) -> u_tilde`` over the worker-
gradient matrix ``u`` of shape ``(m, d)`` (f32).  Attacks are injected
*after* per-worker gradient computation and *before* aggregation — the same
point in the pipeline where the paper's transmission-medium corruption lands.

Classic attacks corrupt whole rows (workers); dimensional attacks corrupt
individual coordinates anywhere in the matrix (Definition 4).

Each attack registers a factory with ``repro.core.registry`` via
``@register_attack`` (recording its kind and the paper's Byzantine count);
``make_attack`` resolves through the registry, so new attacks are
single-file plugins exactly like aggregation rules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.registry import get_attack_spec, register_attack

Attack = Callable[[jax.Array, jax.Array], jax.Array]  # (key, u) -> u_tilde


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """Configuration of the injected failure model."""
    name: str = "none"                 # attack kind
    num_byzantine: int = 0             # q: rows (classic) / values per dim (dimensional)
    gaussian_std: float = 200.0        # paper: std 200
    omniscient_scale: float = 1e20     # paper: 1e20
    bitflip_dims: int = 1000           # paper: first 1000 dimensions
    bitflip_bits: tuple = (22, 30, 31, 32)  # paper: 22th,30th,31th,32th bits (1-indexed)
    gambler_servers: int = 20          # paper: 20 servers
    gambler_prob: float = 0.0005       # paper: 0.05%
    gambler_scale: float = -1e20
    innerprod_scale: float = 2.0       # Fall-of-Empires epsilon
    slowburn_trigger: int = 50         # step at which the colluders strike
    slowburn_scale: float = 100.0      # strike magnitude (innerprod-style)
    slowburn_mimic_std: float = 0.01   # trust-building mimicry noise


# ---------------------------------------------------------------------------
# Classic (row-wise) attacks
# ---------------------------------------------------------------------------

def gaussian_attack(key: jax.Array, u: jax.Array, q: int,
                    std: float = 200.0) -> jax.Array:
    """Replace the first q rows with N(0, std²) noise (§5.1.1)."""
    m, d = u.shape
    noise = std * jax.random.normal(key, (q, d), u.dtype)
    return u.at[:q].set(noise)


def omniscient_attack(key: jax.Array, u: jax.Array, q: int,
                      scale: float = 1e20) -> jax.Array:
    """Replace the first q rows with -scale * sum(correct grads) (§5.1.2)."""
    del key
    correct_sum = jnp.sum(u[q:], axis=0, keepdims=True)
    byz = -scale * correct_sum
    return u.at[:q].set(jnp.broadcast_to(byz, (q, u.shape[1])))


def signflip_attack(key: jax.Array, u: jax.Array, q: int,
                    scale: float = 10.0) -> jax.Array:
    """Beyond-paper: first q rows flipped in sign and scaled."""
    del key
    return u.at[:q].set(-scale * u[:q])


def zero_attack(key: jax.Array, u: jax.Array, q: int) -> jax.Array:
    """Beyond-paper: first q rows zeroed (crash-stop workers)."""
    del key
    return u.at[:q].set(0.0)


def innerprod_attack(key: jax.Array, u: jax.Array, q: int,
                     scale: float = 2.0) -> jax.Array:
    """Inner-product manipulation ("Fall of Empires", Xie et al. 2019).

    The q Byzantine workers collude: each submits ``-eps * mean(correct
    gradients)``.  Unlike the omniscient attack's 1e20 blow-up, ``eps`` is
    O(1), so every Byzantine row has a *benign-looking norm* — it evades
    magnitude-based filtering — while being engineered to drag the
    aggregate's inner product with the true gradient toward/below zero
    (the condition that breaks SGD convergence).  Because the q rows are
    mutually identical they also form the tightest cluster in the matrix,
    the adaptive trap for pairwise-distance rules the paper describes.
    This is the adversary the ``repro.defense`` detector is evaluated
    against (benchmarks/fig_detection.py).
    """
    del key
    correct_mean = jnp.mean(u[q:], axis=0, keepdims=True)
    byz = -scale * correct_mean
    return u.at[:q].set(jnp.broadcast_to(byz, (q, u.shape[1])))


# ---------------------------------------------------------------------------
# Adaptive (step-aware) attacks
# ---------------------------------------------------------------------------

def slowburn_attack(key: jax.Array, u: jax.Array, q: int,
                    step: Optional[jax.Array],
                    trigger: int = 50, scale: float = 100.0,
                    mimic_std: float = 0.01) -> jax.Array:
    """Reputation-EMA slow burn (ROADMAP item c): a colluding adversary that
    *targets the defense's trust state* rather than the aggregation rule.

    Phase 1 (``step < trigger``): the q colluders submit near-perfect copies
    of the benign mean (+ tiny mimicry noise), making them the *most*
    conforming workers in the matrix — suspicion scores stay at the floor
    and the reputation EMA saturates at full trust.

    Phase 2 (``step >= trigger``): a coordinated inner-product strike,
    ``-scale * mean(correct)`` on every colluding row at once.  Because the
    strike lands with maximal banked reputation, the EMA + hysteresis gate
    needs several steps to eject the colluders — the window the attack
    exploits.  The rule-level trim (Phocas/Trmean) still bounds per-step
    damage; what the attack measures is the *defense loop's* reaction lag.

    ``step=None`` (matrix-level tools with no step context) assumes the
    worst case: the strike phase.
    """
    m, d = u.shape
    correct_mean = jnp.mean(u[q:], axis=0, keepdims=True)
    mimic = (jnp.broadcast_to(correct_mean, (q, d))
             + mimic_std * jax.random.normal(key, (q, d), u.dtype))
    strike = jnp.broadcast_to(-scale * correct_mean, (q, d))
    if step is None:
        byz = strike
    else:
        byz = jnp.where(jnp.asarray(step) >= trigger, strike, mimic)
    return u.at[:q].set(byz)


# ---------------------------------------------------------------------------
# Dimensional (generalized) attacks
# ---------------------------------------------------------------------------

def _flip_bits_f32(x: jax.Array, bits: tuple) -> jax.Array:
    """XOR the given bits (1-indexed from the LSB) of each fp32 value.

    IEEE754 single: bit 32 = sign, bits 24-31 = exponent, 1-23 = mantissa.
    The paper's 22/30/31/32 therefore hits a high mantissa bit, the two top
    exponent bits, and the sign — turning O(1) values into O(±1e19) garbage,
    which is what makes the attack destructive (a low-mantissa reading would
    perturb values by ~1e-4 and no defense would even be needed)."""
    mask = 0
    for bit in bits:
        mask |= 1 << (bit - 1)
    xi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jax.lax.bitcast_convert_type(xi ^ jnp.uint32(mask), jnp.float32)


def bitflip_attack(key: jax.Array, u: jax.Array, q: int,
                   num_dims: int = 1000,
                   bits: tuple = (22, 30, 31, 32)) -> jax.Array:
    """§5.1.3: for each of the first ``num_dims`` dimensions, q of the m
    values get their bits flipped.  The corrupted row differs per dimension
    (uniformly random), so every worker row is partially Byzantine — the
    dimensional model of Definition 4."""
    m, d = u.shape
    nd = min(num_dims, d)
    # Choose q distinct rows per attacked dimension.
    scores = jax.random.uniform(key, (m, nd))
    ranks = jnp.argsort(jnp.argsort(scores, axis=0), axis=0)  # 0..m-1 per column
    hit = ranks < q  # (m, nd) — exactly q True per column
    flipped = _flip_bits_f32(u[:, :nd], bits)
    attacked = jnp.where(hit, flipped, u[:, :nd])
    return u.at[:, :nd].set(attacked.astype(u.dtype))


def gambler_attack(key: jax.Array, u: jax.Array,
                   num_servers: int = 20, prob: float = 0.0005,
                   scale: float = -1e20) -> jax.Array:
    """§5.1.4: parameters are partitioned evenly over ``num_servers``; the
    attacker owns server 0 and multiplies each value it relays by ``scale``
    with probability ``prob``.  Corruption hits a contiguous 1/num_servers
    slice of the dimensions, any row."""
    m, d = u.shape
    server_size = max(1, d // num_servers)
    hit = jax.random.bernoulli(key, prob, (m, server_size))
    slice_ = u[:, :server_size]
    attacked = jnp.where(hit, scale * slice_, slice_)
    return u.at[:, :server_size].set(attacked)


# ---------------------------------------------------------------------------
# Registration + dispatch
# ---------------------------------------------------------------------------

@register_attack("gaussian", kind="classic", paper_q=6)
def _gaussian(cfg: AttackConfig) -> Attack:
    return lambda k, u: gaussian_attack(k, u, cfg.num_byzantine,
                                        cfg.gaussian_std)


@register_attack("omniscient", kind="classic", paper_q=6)
def _omniscient(cfg: AttackConfig) -> Attack:
    return lambda k, u: omniscient_attack(k, u, cfg.num_byzantine,
                                          cfg.omniscient_scale)


@register_attack("signflip", kind="classic", paper_q=6)
def _signflip(cfg: AttackConfig) -> Attack:
    return lambda k, u: signflip_attack(k, u, cfg.num_byzantine)


@register_attack("zero", kind="classic", paper_q=6)
def _zero(cfg: AttackConfig) -> Attack:
    return lambda k, u: zero_attack(k, u, cfg.num_byzantine)


@register_attack("innerprod", kind="classic", paper_q=6)
def _innerprod(cfg: AttackConfig) -> Attack:
    return lambda k, u: innerprod_attack(k, u, cfg.num_byzantine,
                                         cfg.innerprod_scale)


@register_attack("slowburn", kind="adaptive", paper_q=6, step_aware=True)
def _slowburn(cfg: AttackConfig) -> Attack:
    return lambda k, u, step=None: slowburn_attack(
        k, u, cfg.num_byzantine, step, cfg.slowburn_trigger,
        cfg.slowburn_scale, cfg.slowburn_mimic_std)


@register_attack("bitflip", kind="dimensional", paper_q=1)
def _bitflip(cfg: AttackConfig) -> Attack:
    return lambda k, u: bitflip_attack(k, u, cfg.num_byzantine,
                                       cfg.bitflip_dims, cfg.bitflip_bits)


@register_attack("gambler", kind="dimensional", paper_q=0)
def _gambler(cfg: AttackConfig) -> Attack:
    return lambda k, u: gambler_attack(k, u, cfg.gambler_servers,
                                       cfg.gambler_prob, cfg.gambler_scale)


def make_attack(cfg: AttackConfig) -> Optional[Attack]:
    """Build a ``(key, u, step=None) -> u_tilde`` closure from the config
    (None = clean).

    Resolves through the attack registry: any ``@register_attack`` plugin
    is reachable by its registered name.  Every returned closure accepts an
    optional trailing ``step`` so the engine can thread the training step
    uniformly; step-oblivious attacks ignore it, step-aware ones
    (``AttackSpec.step_aware``) use it to schedule their phases.
    """
    name = cfg.name.lower()
    if name in ("none", ""):
        return None
    spec = get_attack_spec(name)
    fn = spec.factory(cfg)
    if spec.step_aware:
        return fn
    return lambda key, u, step=None: fn(key, u)


# Deprecated: static snapshots kept for backwards compatibility — the source
# of truth is registry.available_attacks(kind=...), which covers plugins.
CLASSIC_ATTACKS = ("gaussian", "omniscient", "signflip", "zero", "innerprod")
DIMENSIONAL_ATTACKS = ("bitflip", "gambler")
