"""Byzantine attack suite (paper §5 + beyond-paper extensions).

Every attack is a pure function ``(key, u) -> u_tilde`` over the worker-
gradient matrix ``u`` of shape ``(m, d)`` (f32).  Attacks are injected
*after* per-worker gradient computation and *before* aggregation — the same
point in the pipeline where the paper's transmission-medium corruption lands.

Classic attacks corrupt whole rows (workers); dimensional attacks corrupt
individual coordinates anywhere in the matrix (Definition 4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

Attack = Callable[[jax.Array, jax.Array], jax.Array]  # (key, u) -> u_tilde


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """Configuration of the injected failure model."""
    name: str = "none"                 # attack kind
    num_byzantine: int = 0             # q: rows (classic) / values per dim (dimensional)
    gaussian_std: float = 200.0        # paper: std 200
    omniscient_scale: float = 1e20     # paper: 1e20
    bitflip_dims: int = 1000           # paper: first 1000 dimensions
    bitflip_bits: tuple = (22, 30, 31, 32)  # paper: 22th,30th,31th,32th bits (1-indexed)
    gambler_servers: int = 20          # paper: 20 servers
    gambler_prob: float = 0.0005       # paper: 0.05%
    gambler_scale: float = -1e20


# ---------------------------------------------------------------------------
# Classic (row-wise) attacks
# ---------------------------------------------------------------------------

def gaussian_attack(key: jax.Array, u: jax.Array, q: int,
                    std: float = 200.0) -> jax.Array:
    """Replace the first q rows with N(0, std²) noise (§5.1.1)."""
    m, d = u.shape
    noise = std * jax.random.normal(key, (q, d), u.dtype)
    return u.at[:q].set(noise)


def omniscient_attack(key: jax.Array, u: jax.Array, q: int,
                      scale: float = 1e20) -> jax.Array:
    """Replace the first q rows with -scale * sum(correct grads) (§5.1.2)."""
    del key
    correct_sum = jnp.sum(u[q:], axis=0, keepdims=True)
    byz = -scale * correct_sum
    return u.at[:q].set(jnp.broadcast_to(byz, (q, u.shape[1])))


def signflip_attack(key: jax.Array, u: jax.Array, q: int,
                    scale: float = 10.0) -> jax.Array:
    """Beyond-paper: first q rows flipped in sign and scaled."""
    del key
    return u.at[:q].set(-scale * u[:q])


def zero_attack(key: jax.Array, u: jax.Array, q: int) -> jax.Array:
    """Beyond-paper: first q rows zeroed (crash-stop workers)."""
    del key
    return u.at[:q].set(0.0)


# ---------------------------------------------------------------------------
# Dimensional (generalized) attacks
# ---------------------------------------------------------------------------

def _flip_bits_f32(x: jax.Array, bits: tuple) -> jax.Array:
    """XOR the given bits (1-indexed from the LSB) of each fp32 value.

    IEEE754 single: bit 32 = sign, bits 24-31 = exponent, 1-23 = mantissa.
    The paper's 22/30/31/32 therefore hits a high mantissa bit, the two top
    exponent bits, and the sign — turning O(1) values into O(±1e19) garbage,
    which is what makes the attack destructive (a low-mantissa reading would
    perturb values by ~1e-4 and no defense would even be needed)."""
    mask = 0
    for bit in bits:
        mask |= 1 << (bit - 1)
    xi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return jax.lax.bitcast_convert_type(xi ^ jnp.uint32(mask), jnp.float32)


def bitflip_attack(key: jax.Array, u: jax.Array, q: int,
                   num_dims: int = 1000,
                   bits: tuple = (22, 30, 31, 32)) -> jax.Array:
    """§5.1.3: for each of the first ``num_dims`` dimensions, q of the m
    values get their bits flipped.  The corrupted row differs per dimension
    (uniformly random), so every worker row is partially Byzantine — the
    dimensional model of Definition 4."""
    m, d = u.shape
    nd = min(num_dims, d)
    # Choose q distinct rows per attacked dimension.
    scores = jax.random.uniform(key, (m, nd))
    ranks = jnp.argsort(jnp.argsort(scores, axis=0), axis=0)  # 0..m-1 per column
    hit = ranks < q  # (m, nd) — exactly q True per column
    flipped = _flip_bits_f32(u[:, :nd], bits)
    attacked = jnp.where(hit, flipped, u[:, :nd])
    return u.at[:, :nd].set(attacked.astype(u.dtype))


def gambler_attack(key: jax.Array, u: jax.Array,
                   num_servers: int = 20, prob: float = 0.0005,
                   scale: float = -1e20) -> jax.Array:
    """§5.1.4: parameters are partitioned evenly over ``num_servers``; the
    attacker owns server 0 and multiplies each value it relays by ``scale``
    with probability ``prob``.  Corruption hits a contiguous 1/num_servers
    slice of the dimensions, any row."""
    m, d = u.shape
    server_size = max(1, d // num_servers)
    kmask, = jax.random.split(key, 1)
    hit = jax.random.bernoulli(kmask, prob, (m, server_size))
    slice_ = u[:, :server_size]
    attacked = jnp.where(hit, scale * slice_, slice_)
    return u.at[:, :server_size].set(attacked)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def make_attack(cfg: AttackConfig) -> Optional[Attack]:
    """Build a ``(key, u) -> u_tilde`` closure from the config (None = clean)."""
    name = cfg.name.lower()
    if name in ("none", ""):
        return None
    q = cfg.num_byzantine
    table: Dict[str, Attack] = {
        "gaussian": lambda k, u: gaussian_attack(k, u, q, cfg.gaussian_std),
        "omniscient": lambda k, u: omniscient_attack(k, u, q, cfg.omniscient_scale),
        "signflip": lambda k, u: signflip_attack(k, u, q),
        "zero": lambda k, u: zero_attack(k, u, q),
        "bitflip": lambda k, u: bitflip_attack(k, u, q, cfg.bitflip_dims,
                                               cfg.bitflip_bits),
        "gambler": lambda k, u: gambler_attack(k, u, cfg.gambler_servers,
                                               cfg.gambler_prob,
                                               cfg.gambler_scale),
    }
    if name not in table:
        raise ValueError(f"unknown attack {cfg.name!r}; have {sorted(table)}")
    return table[name]


CLASSIC_ATTACKS = ("gaussian", "omniscient", "signflip", "zero")
DIMENSIONAL_ATTACKS = ("bitflip", "gambler")
