"""Theoretical Δ-resilience bounds from the paper (Lemma 1, Theorems 1-2).

These are pure-python helpers used by tests (variance-bound property tests)
and by ``benchmarks/bounds_check.py`` to validate the implementation against
the paper's own theory.
"""
from __future__ import annotations


def check_classic_assumption(m: int, q: int) -> bool:
    """Krum's assumption: 2q + 2 < m (Lemma 1)."""
    return 2 * q + 2 < m


def check_dimensional_assumption(m: int, q: int) -> bool:
    """Trmean/Phocas assumption: 2q < m per dimension (Theorems 1-2)."""
    return 2 * q < m


def delta_krum(m: int, q: int, V: float) -> float:
    """Δ₀ for Krum (Lemma 1, Blanchard et al. Proposition 1)."""
    if not check_classic_assumption(m, q):
        raise ValueError(f"Krum needs 2q+2 < m (m={m}, q={q})")
    return (6 * m - 6 * q
            + (4 * q * (m - q - 2) + 4 * q ** 2 * (m - q - 1)) / (m - 2 * q - 2)) * V


def delta_trmean(m: int, q: int, b: int, V: float) -> float:
    """Δ₁ = 2(b+1)(m-q)/(m-b-q)² · V (Theorem 1). Requires b >= q, 2q < m."""
    if not check_dimensional_assumption(m, q):
        raise ValueError(f"Trmean needs 2q < m (m={m}, q={q})")
    if b < q:
        raise ValueError(f"bound proved for b >= q (b={b}, q={q})")
    return 2.0 * (b + 1) * (m - q) / (m - b - q) ** 2 * V


def delta_phocas(m: int, q: int, b: int, V: float) -> float:
    """Δ₂ = [4 + 12(b+1)(m-q)/(m-b-q)²] · V (Theorem 2)."""
    if not check_dimensional_assumption(m, q):
        raise ValueError(f"Phocas needs 2q < m (m={m}, q={q})")
    if b < q:
        raise ValueError(f"bound proved for b >= q (b={b}, q={q})")
    return (4.0 + 12.0 * (b + 1) * (m - q) / (m - b - q) ** 2) * V


def sgd_convex_error_floor(mu: float, L: float, gamma: float, delta: float) -> float:
    """Constant error term of Theorem 3: (μ+L)/(μL) · γ · √Δ."""
    return (mu + L) / (mu * L) * gamma * delta ** 0.5


def sgd_nonconvex_floor(delta: float) -> float:
    """Stationarity floor of Theorem 4 (the +Δ term)."""
    return delta
