"""Robust aggregation engine: pytree-level + distributed (shard_map) layouts.

Two distributed layouts (see DESIGN.md §2):

* ``replicated`` — paper-faithful PS emulation.  ``all_gather`` the full local
  gradient over the worker axes, every device robust-aggregates the complete
  (m, D_local) matrix redundantly.  Collective bytes ~ m·D per device.

* ``sharded`` — beyond-paper *robust reduce-scatter*.  ``all_to_all`` re-tiles
  the worker-gradient matrix so each device holds (m, D_local/m), aggregates
  its slice once, then ``all_gather`` (tiled) rebuilds the update.  This is the
  paper's own multi-server parameter partitioning (§5.1.4) turned into a TPU
  collective schedule; bytes ~ 2·D, aggregation compute 1/m.

Rule dispatch is fully registry-driven (DESIGN.md §6): ``RobustConfig`` is a
thin serializable spec that resolves to a registered
:class:`repro.core.registry.AggregatorRule`; both layouts simply call the
rule's ``reduce_sharded(mat, psum_axes)`` hook.  Coordinate-wise rules
inherit the slice-local default; vector-wise rules (Krum family, geomedian)
``psum`` their partial per-vector statistics over the dim-sharded worker
axes and the ``model`` (tensor-parallel) axes so selection sees full-vector
geometry.  The engine itself knows no rule names.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import registry
from repro.core.attacks import AttackConfig, make_attack
# The gate lives in core/selection.py (so the registry's default fused hook
# can use it without importing this engine module); re-exported here because
# it is part of the engine's public defense surface.
from repro.core.selection import gate_matrix  # noqa: F401
from repro.dist.collectives import (
    all_to_all_scatter as _a2a_scatter,
    axis_size as _axis_size,
    gather_slices as _gather_slices,
    gather_workers as _gather_workers,
    worker_slice_index as _worker_slice_index,
)


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Serializable spec of the robust-aggregation stage of ``train_step``.

    ``rule`` names any registered aggregation rule (see
    ``registry.available_rules()``); all rule parameters are plain fields so
    the config round-trips through JSON/argparse, and ``rule_obj()`` resolves
    the spec to a bound rule object through the registry.
    """
    rule: str = "phocas"          # any registered rule name
    b: int = 2                    # trim parameter (trmean/phocas family)
    q: int = 2                    # assumed Byzantine count (krum family)
    multikrum_k: Optional[int] = None  # Multi-Krum selection size (None = m-q-2)
    geomedian_iters: int = 8      # Weiszfeld iteration count
    layout: str = "sharded"       # replicated | sharded
    backend: str = "auto"         # auto | pallas | xla (per-rule resolution)
    agg_dtype: str = "float32"    # robust statistics dtype
    attack: AttackConfig = dataclasses.field(default_factory=AttackConfig)
    # Deprecated alias for backend= (True -> "pallas", False -> "xla").
    use_kernels: dataclasses.InitVar[Optional[bool]] = None

    def __post_init__(self, use_kernels: Optional[bool]):
        if use_kernels is not None:
            warnings.warn(
                "RobustConfig(use_kernels=...) is deprecated; use "
                "backend='pallas'|'xla'|'auto'", DeprecationWarning,
                stacklevel=3)
            object.__setattr__(self, "backend",
                               "pallas" if use_kernels else "xla")

    def rule_params(self) -> registry.RuleParams:
        return registry.RuleParams(
            b=self.b, q=self.q, multikrum_k=self.multikrum_k,
            geomedian_iters=self.geomedian_iters, backend=self.backend)

    def rule_obj(self) -> registry.AggregatorRule:
        """Resolve this spec to a bound rule object via the registry."""
        return registry.make_rule(self.rule, self.rule_params())

    def aggregator(self):
        """Unary ``(m, ...) -> (...)`` closure (registry-resolved)."""
        return self.rule_obj().reduce


# ---------------------------------------------------------------------------
# Local (single host / test) path
# ---------------------------------------------------------------------------

def aggregate_matrix(u: jax.Array, cfg: RobustConfig,
                     key: Optional[jax.Array] = None, *,
                     active: Optional[jax.Array] = None,
                     with_scores: bool = False,
                     step: Optional[jax.Array] = None):
    """Aggregate an (m, d) worker matrix, optionally injecting the attack.

    ``active`` applies the reputation gate (after the attack — the defense
    never sees pre-corruption data); ``with_scores=True`` returns
    ``(agg, scores)`` via the rule's ``reduce_with_scores`` hook.  ``step``
    is the training step, forwarded to step-aware (adaptive) attacks;
    without it those attacks assume their worst-case phase.

    Scoring always observes the RAW submissions while the aggregate uses
    the gated matrix: if ejected rows were also replaced for scoring, an
    ejected worker would instantly look conforming, recover reputation,
    and be readmitted while still misbehaving (eject/readmit flapping).
    Readmission must be earned by actually-clean submissions."""
    attack = make_attack(cfg.attack)
    uf = u.astype(cfg.agg_dtype)
    if attack is not None:
        if key is None:
            raise ValueError("attack configured but no PRNG key supplied")
        uf = attack(key, uf, step)
    rule = cfg.rule_obj()
    if with_scores:
        # One fused hook: raw-submission scores + gated aggregate.  The
        # registry default composes the old two-pass path; the trim-family
        # rules override it with a single shared selection pass.
        return rule.reduce_gated_with_scores(uf, active)
    if active is not None:
        uf = gate_matrix(uf, active)
    return rule.reduce(uf)


def aggregate_stacked_tree(stacked, cfg: RobustConfig,
                           key: Optional[jax.Array] = None, *,
                           active: Optional[jax.Array] = None,
                           with_scores: bool = False,
                           step: Optional[jax.Array] = None):
    """Aggregate a pytree whose leaves are stacked (m, *leaf_shape) arrays.

    Flattens to a single (m, D) matrix so vector-wise rules (krum) see full
    gradient geometry, then unflattens the aggregated vector.  With
    ``with_scores=True`` returns ``(tree, scores)``.
    """
    leaves = jax.tree_util.tree_leaves(stacked)
    m = leaves[0].shape[0]
    # ravel each worker's slice identically
    flat0, unravel = ravel_pytree(jax.tree.map(lambda x: x[0], stacked))
    mat = jax.vmap(lambda i: ravel_pytree(
        jax.tree.map(lambda x: x[i], stacked))[0])(jnp.arange(m))
    out = aggregate_matrix(mat, cfg, key, active=active,
                           with_scores=with_scores, step=step)
    if with_scores:
        agg, scores = out
        return unravel(agg.astype(flat0.dtype)), scores
    return unravel(out.astype(flat0.dtype))


# ---------------------------------------------------------------------------
# Distributed path (must be called inside shard_map)
# ---------------------------------------------------------------------------

def robust_aggregate_dist(grad_tree, cfg: RobustConfig,
                          worker_axes: Sequence[str],
                          model_axes: Sequence[str] = (),
                          key: Optional[jax.Array] = None,
                          active: Optional[jax.Array] = None,
                          with_scores: bool = False,
                          step: Optional[jax.Array] = None):
    """Aggregate per-worker gradient pytrees inside ``shard_map``.

    Args:
      grad_tree: the *local* gradient pytree (this worker-shard's gradient,
        already psum'd over ``model_axes`` microbatch internals as needed).
      cfg: robust config (rule, layout, simulated attack).
      worker_axes: mesh axes playing the paper's "worker" role, e.g.
        ``("data",)`` or ``("pod", "data")``.
      model_axes: tensor-parallel axes (needed only by vector-wise rules'
        partial-statistic psums).
      key: per-step PRNG key (replicated), required when an attack is set.
      step: replicated training-step scalar, forwarded to step-aware
        (adaptive) attacks; None = worst-case phase.
      active: replicated (m,) reputation mask — ejected workers' rows are
        gated (``gate_matrix``) before the rule runs.
      with_scores: also return the rule's per-worker suspicion scores,
        psum'd over the layout's sharded axes so they come back replicated
        (the ``repro.defense`` contract, DESIGN.md §7).

    Returns the aggregated gradient pytree with the input structure/dtypes
    (plus the (m,) scores when ``with_scores``).
    """
    worker_axes = tuple(worker_axes)
    m = _axis_size(worker_axes)
    flat, unravel = ravel_pytree(grad_tree)
    flat = flat.astype(cfg.agg_dtype)
    d = flat.shape[0]
    pad = (-d) % m
    if pad:
        flat = jnp.pad(flat, (0, pad))

    attack = make_attack(cfg.attack)
    rule = cfg.rule_obj()

    def _reduce(mat, psum_axes):
        # Scores observe RAW submissions; the aggregate uses the gated
        # matrix (see aggregate_matrix: prevents eject/readmit flapping).
        # Both come out of the one fused hook.
        if with_scores:
            return rule.reduce_sharded_gated_with_scores(mat, active,
                                                         psum_axes)
        if active is not None:
            mat = gate_matrix(mat, active)
        return rule.reduce_sharded(mat, psum_axes), None

    if cfg.layout == "replicated":
        mat = _gather_workers(flat, worker_axes)          # (m, D)
        if attack is not None:
            mat = attack(key, mat, step)
        agg, scores = _reduce(mat, tuple(model_axes))      # (D,)
    elif cfg.layout == "sharded":
        mat = _a2a_scatter(flat, worker_axes)             # (m, D/m)
        if attack is not None:
            # Each device is a "server" owning a slice of the dims — exactly
            # the paper's §5.1.4 multi-server partitioning.
            key = jax.random.fold_in(key, _worker_slice_index(worker_axes)) \
                if key is not None else None
            mat = attack(key, mat, step)
        agg_slice, scores = _reduce(
            mat, worker_axes + tuple(model_axes))         # (D/m,)
        agg = _gather_slices(agg_slice, worker_axes)      # (D,)
    else:
        raise ValueError(f"unknown layout {cfg.layout!r}")

    if pad:
        agg = agg[:d]
    agg_tree = unravel(agg.astype(ravel_pytree(grad_tree)[0].dtype))
    if with_scores:
        return agg_tree, scores
    return agg_tree
