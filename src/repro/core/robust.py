"""Robust aggregation engine: pytree-level + distributed (shard_map) layouts.

Two distributed layouts (see DESIGN.md §2):

* ``replicated`` — paper-faithful PS emulation.  ``all_gather`` the full local
  gradient over the worker axes, every device robust-aggregates the complete
  (m, D_local) matrix redundantly.  Collective bytes ~ m·D per device.

* ``sharded`` — beyond-paper *robust reduce-scatter*.  ``all_to_all`` re-tiles
  the worker-gradient matrix so each device holds (m, D_local/m), aggregates
  its slice once, then ``all_gather`` (tiled) rebuilds the update.  This is the
  paper's own multi-server parameter partitioning (§5.1.4) turned into a TPU
  collective schedule; bytes ~ 2·D, aggregation compute 1/m.

Both layouts support the coordinate-wise rules directly; Krum-family rules
additionally ``psum`` partial pairwise squared distances over the worker axes
(sharded) and over the ``model`` axis (tensor-parallel shards), so vector-wise
selection sees full-vector geometry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import aggregators
from repro.core.attacks import AttackConfig, make_attack
from repro.dist.collectives import (
    all_to_all_scatter as _a2a_scatter,
    axis_size as _axis_size,
    gather_slices as _gather_slices,
    gather_workers as _gather_workers,
    psum_axes as _psum_axes,
    worker_slice_index as _worker_slice_index,
)


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Configuration of the robust-aggregation stage of ``train_step``."""
    rule: str = "phocas"          # mean|median|trmean|phocas|krum|multikrum|geomedian
    b: int = 2                    # trim parameter (trmean/phocas)
    q: int = 2                    # assumed Byzantine count (krum family)
    layout: str = "sharded"       # replicated | sharded
    use_kernels: bool = False     # route trmean/phocas through Pallas ops
    agg_dtype: str = "float32"    # robust statistics dtype
    attack: AttackConfig = dataclasses.field(default_factory=AttackConfig)

    def aggregator(self):
        if self.use_kernels and self.rule in ("trmean", "phocas"):
            from repro.kernels import ops as kops  # lazy: avoid import cycle
            if self.rule == "trmean":
                return lambda u: kops.trmean(u, self.b)
            return lambda u: kops.phocas(u, self.b)
        return aggregators.get_aggregator(self.rule, b=self.b, q=self.q)


# ---------------------------------------------------------------------------
# Local (single host / test) path
# ---------------------------------------------------------------------------

def aggregate_matrix(u: jax.Array, cfg: RobustConfig,
                     key: Optional[jax.Array] = None) -> jax.Array:
    """Aggregate an (m, d) worker matrix, optionally injecting the attack."""
    attack = make_attack(cfg.attack)
    uf = u.astype(cfg.agg_dtype)
    if attack is not None:
        if key is None:
            raise ValueError("attack configured but no PRNG key supplied")
        uf = attack(key, uf)
    return cfg.aggregator()(uf)


def aggregate_stacked_tree(stacked, cfg: RobustConfig,
                           key: Optional[jax.Array] = None):
    """Aggregate a pytree whose leaves are stacked (m, *leaf_shape) arrays.

    Flattens to a single (m, D) matrix so vector-wise rules (krum) see full
    gradient geometry, then unflattens the aggregated vector.
    """
    leaves = jax.tree_util.tree_leaves(stacked)
    m = leaves[0].shape[0]
    # ravel each worker's slice identically
    flat0, unravel = ravel_pytree(jax.tree.map(lambda x: x[0], stacked))
    mat = jax.vmap(lambda i: ravel_pytree(
        jax.tree.map(lambda x: x[i], stacked))[0])(jnp.arange(m))
    agg = aggregate_matrix(mat, cfg, key)
    return unravel(agg.astype(flat0.dtype))


# ---------------------------------------------------------------------------
# Distributed path (must be called inside shard_map)
# ---------------------------------------------------------------------------

def _krum_select(mat: jax.Array, cfg: RobustConfig,
                 psum_axes: Tuple[str, ...]) -> jax.Array:
    """Krum-family selection with distance partial-sums psum'd over
    ``psum_axes`` (dim-sharded and/or model-sharded portions)."""
    m = mat.shape[0]
    sq = jnp.sum(mat * mat, axis=1)
    gram = mat @ mat.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    d2 = _psum_axes(d2, psum_axes)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    k = m - cfg.q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m-q-2 > 0 (m={m}, q={cfg.q})")
    nearest = jnp.sort(d2, axis=1)[:, :k]
    scores = jnp.sum(nearest, axis=1)
    if cfg.rule == "krum":
        return mat[jnp.argmin(scores)]
    _, idx = jax.lax.top_k(-scores, k)   # multikrum
    return jnp.mean(mat[idx], axis=0)


def _geomedian_dist(mat: jax.Array, psum_axes: Tuple[str, ...],
                    iters: int = 8, eps: float = 1e-8) -> jax.Array:
    """Weiszfeld iterations on a dim-sharded (m, D_slice) matrix: partial
    squared distances are psum'd over ``psum_axes`` so weights use the full
    vector geometry while updates stay slice-local."""
    def step(z, _):
        d2 = jnp.sum((mat - z[None]) ** 2, axis=1)
        d2 = _psum_axes(d2, psum_axes)
        w = 1.0 / jnp.maximum(jnp.sqrt(d2), eps)
        z_new = jnp.sum(mat * w[:, None], axis=0) / jnp.sum(w)
        return z_new, None

    z, _ = jax.lax.scan(step, jnp.mean(mat, axis=0), None, length=iters)
    return z


def robust_aggregate_dist(grad_tree, cfg: RobustConfig,
                          worker_axes: Sequence[str],
                          model_axes: Sequence[str] = (),
                          key: Optional[jax.Array] = None):
    """Aggregate per-worker gradient pytrees inside ``shard_map``.

    Args:
      grad_tree: the *local* gradient pytree (this worker-shard's gradient,
        already psum'd over ``model_axes`` microbatch internals as needed).
      cfg: robust config (rule, layout, simulated attack).
      worker_axes: mesh axes playing the paper's "worker" role, e.g.
        ``("data",)`` or ``("pod", "data")``.
      model_axes: tensor-parallel axes (needed only by Krum-family distances).
      key: per-step PRNG key (replicated), required when an attack is set.

    Returns the aggregated gradient pytree with the input structure/dtypes.
    """
    worker_axes = tuple(worker_axes)
    m = _axis_size(worker_axes)
    flat, unravel = ravel_pytree(grad_tree)
    flat = flat.astype(cfg.agg_dtype)
    d = flat.shape[0]
    pad = (-d) % m
    if pad:
        flat = jnp.pad(flat, (0, pad))

    attack = make_attack(cfg.attack)
    vector_wise = cfg.rule in aggregators.VECTOR_WISE

    if cfg.layout == "replicated":
        mat = _gather_workers(flat, worker_axes)          # (m, D)
        if attack is not None:
            mat = attack(key, mat)
        if cfg.rule == "geomedian":
            agg = _geomedian_dist(mat, tuple(model_axes))
        elif vector_wise:
            agg = _krum_select(mat, cfg, tuple(model_axes))
        else:
            agg = cfg.aggregator()(mat)                   # (D,)
    elif cfg.layout == "sharded":
        mat = _a2a_scatter(flat, worker_axes)             # (m, D/m)
        if attack is not None:
            # Each device is a "server" owning a slice of the dims — exactly
            # the paper's §5.1.4 multi-server partitioning.
            key = jax.random.fold_in(key, _worker_slice_index(worker_axes)) \
                if key is not None else None
            mat = attack(key, mat)
        if cfg.rule == "geomedian":
            agg_slice = _geomedian_dist(mat, worker_axes + tuple(model_axes))
        elif vector_wise:
            agg_slice = _krum_select(mat, cfg,
                                     worker_axes + tuple(model_axes))
        else:
            agg_slice = cfg.aggregator()(mat)             # (D/m,)
        agg = _gather_slices(agg_slice, worker_axes)      # (D,)
    else:
        raise ValueError(f"unknown layout {cfg.layout!r}")

    if pad:
        agg = agg[:d]
    return unravel(agg.astype(ravel_pytree(grad_tree)[0].dtype))
