"""Pluggable Rule/Attack registry — the aggregation stack's single dispatch
point.

The paper evaluates a *family* of aggregation rules against a *family* of
attacks; its companion (Xie et al. 2018, "Generalized Byzantine-tolerant
SGD") adds more of each.  This module makes both families open-ended:

* A rule is a subclass of :class:`AggregatorRule` decorated with
  :func:`register_rule`.  The class carries the metadata the rest of the
  stack needs (``coordinate_wise``, ``resilience``, which parameters it
  consumes, whether a Pallas kernel exists) and implements ``_reduce_xla``
  (plus, optionally, ``_reduce_pallas`` and ``reduce_sharded``).  Everything
  else — ``RobustConfig`` resolution, the distributed engine in
  ``core/robust.py``, the train CLI, the fig2/fig3 benchmark sweeps, the
  registry round-trip tests — enumerates the registry, so **adding a rule is
  one new module + one ``@register_rule`` call** (see
  ``repro/core/rules/mediam.py`` for the template).

* An attack is a factory ``AttackConfig -> (key, u) -> u_tilde`` decorated
  with :func:`register_attack`; the decorator records the attack's kind
  (classic row-wise vs dimensional, Definition 4) and the Byzantine count
  the paper's experiments use, which the benchmarks read back.

Built-in rules/attacks register themselves when ``repro.core.aggregators`` /
``repro.core.attacks`` / the ``repro.core.rules`` plugin package import;
every lookup triggers those imports lazily, so the registry is populated no
matter which module is imported first.

Backend resolution replaces the old ``use_kernels`` bool: each rule resolves
``backend="auto"|"pallas"|"xla"`` against its declared kernels —
``"pallas"`` demands a kernel (and errors on rules without one), ``"xla"``
forces the pure-jnp path, and ``"auto"`` picks the kernel exactly when one
exists and the runtime backend is not the CPU interpreter.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Dict, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp

Attack = Callable[[jax.Array, jax.Array], jax.Array]  # (key, u) -> u_tilde

BACKENDS = ("auto", "pallas", "xla")


@dataclasses.dataclass(frozen=True)
class RuleParams:
    """The union of per-rule parameters a registered rule may consume.

    A thin, serializable value object: ``RobustConfig`` produces one, the
    registry binds it to a rule class.  Each rule reads only the fields its
    metadata declares (``uses_b`` / ``uses_q`` / ...).
    """
    b: int = 0                            # trim count (trmean/phocas family)
    q: int = 0                            # assumed Byzantine count (Krum family)
    multikrum_k: Optional[int] = None     # Multi-Krum selection size (None = m-q-2)
    geomedian_iters: int = 8              # Weiszfeld iteration count
    backend: str = "auto"                 # auto | pallas | xla


class AggregatorRule:
    """Base class for registered aggregation rules.

    Subclass, set the metadata classvars, implement ``_reduce_xla`` (and
    optionally ``_reduce_pallas`` with ``has_kernel = True``, and
    ``reduce_sharded``), then decorate with :func:`register_rule`.

    The ``reduce_sharded(mat, psum_axes)`` contract (DESIGN.md §6): called
    inside ``shard_map`` on the (m, D_slice) worker matrix this device owns.
    Coordinate-wise rules inherit the default (each coordinate is
    independent, so the slice-local ``reduce`` is exact).  Vector-wise rules
    MUST override it and ``psum`` their per-vector partial statistics
    (pairwise distances, Weiszfeld weights, ...) over ``psum_axes`` so
    selection sees full-vector geometry while outputs stay slice-local.
    """

    # --- metadata (override in subclasses) ---
    name: ClassVar[str]
    coordinate_wise: ClassVar[bool] = True
    resilience: ClassVar[str] = "none"    # dimensional | classic | none
    uses_b: ClassVar[bool] = False        # consumes RuleParams.b
    uses_q: ClassVar[bool] = False        # consumes RuleParams.q
    has_kernel: ClassVar[bool] = False    # declares a Pallas _reduce_pallas
    supports_streaming: ClassVar[bool] = False  # train/streaming.py scan mode
    emits_scores: ClassVar[bool] = False  # informative reduce_with_scores
    fused_gate: ClassVar[bool] = False    # one-pass gated override (the
    # defense path may call reduce_sharded_gated_with_scores every step;
    # False means the base two-pass composition runs — correct but ~2x,
    # and for the vector-wise rules BENCH_agg_scaling showed 1.4-2.6x —
    # so rules keep this honest and repro.analysis CONTRACT007 checks it)

    def __init__(self, params: RuleParams = RuleParams()):
        self.params = params
        self.backend = resolve_backend(type(self), params.backend)

    # --- public API ---

    def reduce(self, u: jax.Array) -> jax.Array:
        """Aggregate an (m, ...) worker matrix to (...)."""
        if self.backend == "pallas":
            return self._reduce_pallas(u)
        return self._reduce_xla(u)

    def reduce_sharded(self, mat: jax.Array,
                       psum_axes: Sequence[str]) -> jax.Array:
        """Aggregate this device's (m, D_slice) inside ``shard_map``."""
        if not self.coordinate_wise and tuple(psum_axes):
            raise NotImplementedError(
                f"vector-wise rule {self.name!r} must override reduce_sharded "
                "(its statistics need a psum over the sharded axes)")
        return self.reduce(mat)

    def reduce_with_scores(self, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Aggregate an (m, ...) matrix AND emit per-worker suspicion scores.

        Returns ``(agg, scores)`` where ``scores`` has shape ``(m,)``, lies
        in ``[0, 1]``, and larger means more suspicious (``repro.defense``
        score contract, DESIGN.md §7).  Rules whose internal statistics
        carry a per-worker signal — which values the trim step dropped, the
        Krum pairwise-distance sums, the Weiszfeld weights — override
        :meth:`reduce_sharded_with_scores` and set ``emits_scores = True``;
        everything else (``mean``, ...) inherits this uninformative uniform
        default (all-zero scores).
        """
        return self.reduce_sharded_with_scores(u, ())

    def reduce_sharded_with_scores(
            self, mat: jax.Array,
            psum_axes: Sequence[str]) -> Tuple[jax.Array, jax.Array]:
        """Sharded analogue of :meth:`reduce_with_scores`: called inside
        ``shard_map`` on this device's (m, D_slice) matrix.  Returned scores
        MUST already be psum'd over ``psum_axes`` (the dimension-sharded
        worker axes plus the model axes) so every device holds identical
        *global* per-worker suspicion — the same contract the Krum partial
        distances follow.  Empty ``psum_axes`` = the single-device call."""
        agg = self.reduce_sharded(mat, psum_axes)
        return agg, jnp.zeros((mat.shape[0],), jnp.float32)

    def reduce_gated_with_scores(
            self, u: jax.Array,
            active: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Fused defense step: reputation-gated aggregate + raw scores."""
        return self.reduce_sharded_gated_with_scores(u, active, ())

    def reduce_sharded_gated_with_scores(
            self, mat: jax.Array, active: Optional[jax.Array],
            psum_axes: Sequence[str]) -> Tuple[jax.Array, jax.Array]:
        """The defense-enabled aggregation in ONE hook (DESIGN.md §8).

        Returns ``(agg, scores)`` where ``scores`` observe the RAW
        submissions (the flap-prevention invariant of §7) while ``agg``
        aggregates the reputation-gated matrix (``active`` ejected rows
        replaced by the raw median row; ``active=None`` = no gate).

        This default composes the two existing hooks — semantically the
        pre-fusion two-pass path.  Rules whose selection state covers both
        outputs (the coordinate-wise trim family) override it so the gate's
        median row, the score masks, and the gated re-aggregation all read
        one shared selection pass instead of running the rule twice.
        """
        agg, scores = self.reduce_sharded_with_scores(mat, psum_axes)
        if active is not None:
            from repro.core.selection import gate_matrix
            agg = self.reduce_sharded(gate_matrix(mat, active), psum_axes)
        return agg, scores

    # --- implementations (override) ---

    def _reduce_xla(self, u: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _reduce_pallas(self, u: jax.Array) -> jax.Array:
        raise NotImplementedError(
            f"rule {self.name!r} sets has_kernel but lacks _reduce_pallas")


# ---------------------------------------------------------------------------
# Suspicion-score contract (DESIGN.md §7)
# ---------------------------------------------------------------------------
# Scores returned by reduce_with_scores / reduce_sharded_with_scores have
# shape (m,), live in [0, 1]; 0 = conforming, 1 = maximally suspicious.  In
# sharded layouts the raw statistics are psum'd BEFORE normalization.  The
# normalizers live here (not in repro.defense) so the core import graph
# stays closed — repro.defense.scores re-exports them.

def drop_frequency_scores(drop_counts: jax.Array, ncoords: jax.Array,
                          baseline: float) -> jax.Array:
    """Normalize per-worker trim/drop counts into suspicion scores.

    ``drop_counts[i]`` = number of coordinates where worker i's value was
    dropped by the rule's selection step; ``ncoords`` = total coordinates
    counted (both already psum'd in sharded layouts).  ``baseline`` is the
    frequency an exchangeable benign worker expects (trmean drops exactly
    2b of m values per coordinate -> 2b/m; phocas/mediam drop b -> b/m), so
    benign workers land near 0 and a consistently-trimmed Byzantine worker
    near 1.
    """
    freq = drop_counts / jnp.maximum(ncoords, 1.0)
    denom = jnp.maximum(1.0 - baseline, 1e-6)
    return jnp.clip((freq - baseline) / denom, 0.0, 1.0)


def distance_ratio_scores(raw: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Normalize nonnegative per-worker distance statistics (Krum score
    sums, Weiszfeld distances) into suspicion scores.

    Distance statistics have multiplicative, scale-free spread, so the
    robust reference is the median: ``1 - median/raw`` maps the median
    worker to 0 and far outliers toward 1.  A degenerate distribution
    (median ~ 0, e.g. a clean all-identical matrix) yields all-zero scores
    rather than amplifying noise.
    """
    med = jnp.median(raw)
    s = jnp.clip(1.0 - med / jnp.maximum(raw, eps), 0.0, 1.0)
    return jnp.where(med <= eps, jnp.zeros_like(s), s)


def resolve_backend(rule_cls: Type[AggregatorRule], requested: str) -> str:
    """Resolve a requested backend against the rule's declared kernels."""
    if requested not in BACKENDS:
        raise ValueError(f"unknown backend {requested!r}; have {BACKENDS}")
    if requested == "pallas":
        if not rule_cls.has_kernel:
            raise ValueError(
                f"backend='pallas' but rule {rule_cls.name!r} declares no "
                f"kernel; rules with kernels: {kernel_rules()}")
        return "pallas"
    if requested == "xla":
        return "xla"
    # auto: use the kernel when one exists and Pallas would actually compile
    # (on CPU it runs in interpret mode — strictly slower than XLA).
    if rule_cls.has_kernel and jax.default_backend() != "cpu":
        return "pallas"
    return "xla"


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

_RULES: Dict[str, Type[AggregatorRule]] = {}


def register_rule(cls: Type[AggregatorRule]) -> Type[AggregatorRule]:
    """Class decorator: make ``cls`` available to the whole stack by name."""
    name = cls.name.lower()
    prev = _RULES.get(name)
    if prev is not None and prev is not cls:
        raise ValueError(f"aggregation rule {name!r} already registered "
                         f"by {prev.__module__}.{prev.__qualname__}")
    _RULES[name] = cls
    return cls


def _ensure_builtins() -> None:
    # Deferred: these modules import this one for the decorators.
    import repro.core.aggregators  # noqa: F401
    import repro.core.attacks      # noqa: F401
    import repro.core.rules        # noqa: F401  (single-file plugins)


def available_rules() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_RULES))


def get_rule(name: str) -> Type[AggregatorRule]:
    _ensure_builtins()
    key = name.lower()
    if key not in _RULES:
        raise ValueError(f"unknown aggregation rule {name!r}; "
                         f"have {sorted(_RULES)}")
    return _RULES[key]


def make_rule(name: str, params: RuleParams = RuleParams()) -> AggregatorRule:
    return get_rule(name)(params)


def coordinate_wise_rules() -> Tuple[str, ...]:
    return tuple(n for n in available_rules() if _RULES[n].coordinate_wise)


def vector_wise_rules() -> Tuple[str, ...]:
    return tuple(n for n in available_rules() if not _RULES[n].coordinate_wise)


def kernel_rules() -> Tuple[str, ...]:
    return tuple(n for n in available_rules() if _RULES[n].has_kernel)


def streaming_rules() -> Tuple[str, ...]:
    return tuple(n for n in available_rules() if _RULES[n].supports_streaming)


def score_rules() -> Tuple[str, ...]:
    """Rules whose ``reduce_with_scores`` emits informative suspicion."""
    return tuple(n for n in available_rules() if _RULES[n].emits_scores)


def fused_gate_rules() -> Tuple[str, ...]:
    """Rules whose gated defense hook is a genuine one-pass override."""
    return tuple(n for n in available_rules() if _RULES[n].fused_gate)


def robust_rules() -> Tuple[str, ...]:
    """Rules with any resilience claim (classic or dimensional)."""
    return tuple(n for n in available_rules()
                 if _RULES[n].resilience != "none")


# ---------------------------------------------------------------------------
# Attack registry
# ---------------------------------------------------------------------------

ATTACK_KINDS = ("classic", "dimensional", "adaptive")


@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """A registered attack: factory + the metadata the benchmarks read.

    ``step_aware`` marks attacks whose behavior depends on the training
    step (the adaptive trust-building adversaries): their closures take a
    third ``step`` argument, threaded from the train step's optimizer
    state.  Called without a step they assume the worst case (post-trigger
    strike phase), so matrix-level tools stay usable.
    """
    name: str
    factory: Callable[..., Attack]        # AttackConfig -> Attack closure
    kind: str                             # classic | dimensional | adaptive
    paper_q: int = 0                      # Byzantine count in the paper's runs
    step_aware: bool = False              # closure reads the training step


_ATTACKS: Dict[str, AttackSpec] = {}


def register_attack(name: str, *, kind: str, paper_q: int = 0,
                    step_aware: bool = False):
    """Decorator for attack factories ``AttackConfig -> (key, u) -> u~``."""
    if kind not in ATTACK_KINDS:
        raise ValueError(
            f"attack kind must be one of {ATTACK_KINDS}, got {kind!r}")

    def deco(factory):
        key = name.lower()
        prev = _ATTACKS.get(key)
        if prev is not None and prev.factory is not factory:
            raise ValueError(f"attack {key!r} already registered")
        _ATTACKS[key] = AttackSpec(name=key, factory=factory, kind=kind,
                                   paper_q=paper_q, step_aware=step_aware)
        return factory

    return deco


def available_attacks(kind: Optional[str] = None) -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(n for n in sorted(_ATTACKS)
                 if kind is None or _ATTACKS[n].kind == kind)


def get_attack_spec(name: str) -> AttackSpec:
    _ensure_builtins()
    key = name.lower()
    if key not in _ATTACKS:
        raise ValueError(f"unknown attack {name!r}; have {sorted(_ATTACKS)}")
    return _ATTACKS[key]
