"""Marginal median-of-means ("mom") — the marginal-median family of the
companion paper (Xie et al. 2018) crossed with the classic median-of-means
estimator.

Workers are partitioned round-robin into g = min(2b + 1, m) groups; each
group's gradients are averaged, then the coordinate-wise (marginal) median
of the g group means is taken.  Each Byzantine worker can poison at most
one group, so with b Byzantine workers at most b of the 2b + 1 group means
are corrupted per coordinate — a strict minority, and the marginal median
of the rest stays inside the correct values' range (the same Lemma-2-style
argument as trmean).  Compared to trmean the estimator keeps more of the
averaging variance reduction (each kept statistic is already a mean over
~m/g workers) at the cost of a coarser order statistic.

Single-file plugin: see ``repro/core/rules/mediam.py`` for the template.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.core.registry import AggregatorRule, register_rule


@register_rule
class MarginalMedianOfMeans(AggregatorRule):
    name = "mom"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True

    def _reduce_xla(self, u: jax.Array) -> jax.Array:
        m = u.shape[0]
        b = self.params.b
        if not 0 <= b <= (m + 1) // 2 - 1:
            raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")
        uf = u.astype(jnp.float32) if u.dtype != jnp.float32 else u
        g = min(2 * b + 1, m)
        if g <= 1:
            return jnp.mean(uf, axis=0)
        gid = jnp.arange(m) % g
        onehot = (gid[None, :] == jnp.arange(g)[:, None]).astype(uf.dtype)
        counts = jnp.sum(onehot, axis=1)              # (g,)
        sums = jnp.tensordot(onehot, uf, axes=(1, 0))  # (g, *trailing)
        means = sums / counts.reshape((g,) + (1,) * (uf.ndim - 1))
        # marginal median over the g group means via the shared network
        # (g is small, so the fused row-op path beats jnp.median's sort)
        return selection.matrix_median(means)
