"""Single-file aggregation-rule plugins.

Each module in this package defines ONE rule: an
``repro.core.registry.AggregatorRule`` subclass with a ``@register_rule``
decoration.  Every module here is imported automatically (below), and the
registry imports this package lazily on any lookup — so **dropping a new
file in this directory is all the wiring a rule needs**.  It then appears
in ``get_aggregator``, ``RobustConfig`` resolution, the train CLI choices,
the fig2/fig3 benchmark sweeps, and the registry round-trip tests.  Copy
``mediam.py`` as the template.
"""
import importlib
import pkgutil

for _mod in pkgutil.iter_modules(__path__):
    importlib.import_module(f"{__name__}.{_mod.name}")
del importlib, pkgutil, _mod
