"""Mean-around-median ("mediam") — Xie et al. 2018, "Generalized
Byzantine-tolerant SGD" (the paper's companion).

Per coordinate: take the coordinate-wise median as the center, keep the
(m - b) values nearest to it, and average them.  Structurally Phocas
(Definition 8) with the median replacing the b-trimmed mean as the center —
the same dimensional resilience class, one fewer tunable (the median needs
no trim parameter), slightly looser variance constant.

This module is the single-file plugin template: the class below plus its
``@register_rule`` decoration is ALL that is needed for the rule to appear
in ``get_aggregator``, the train CLI, the fig2/fig3 sweeps, and the
registry round-trip tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import AggregatorRule, register_rule


@register_rule
class MeanAroundMedian(AggregatorRule):
    name = "mediam"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    emits_scores = True

    @staticmethod
    def _stats(u: jax.Array, b: int):
        """(agg, drop_counts (m,), ncoords) — the selection mask doubles as
        the rule's per-worker suspicion signal (DESIGN.md §7)."""
        from repro.core.aggregators import _ncoords_of
        m = u.shape[0]
        if not 0 <= b <= (m + 1) // 2 - 1:
            raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")
        uf = u.astype(jnp.float32) if u.dtype != jnp.float32 else u
        if b == 0:
            return (jnp.mean(uf, axis=0), jnp.zeros((m,), jnp.float32),
                    _ncoords_of(u))
        center = jnp.median(uf, axis=0)
        dist = jnp.abs(uf - center[None])
        order = jnp.argsort(dist, axis=0)             # ascending distance
        ranks = jnp.argsort(order, axis=0)            # per-coordinate rank
        dropped = ranks >= (m - b)
        counts = jnp.sum(dropped, axis=tuple(range(1, uf.ndim))
                         ).astype(jnp.float32)
        agg = jnp.sum(uf * (~dropped).astype(uf.dtype), axis=0) / (m - b)
        return agg, counts, _ncoords_of(u)

    def _reduce_xla(self, u: jax.Array) -> jax.Array:
        return self._stats(u, self.params.b)[0]

    def reduce_sharded_with_scores(self, mat, psum_axes):
        from repro.core.aggregators import trim_mask_scores
        return trim_mask_scores(self._stats, mat, self.params.b,
                                float(self.params.b) / mat.shape[0],
                                psum_axes)
