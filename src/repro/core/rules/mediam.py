"""Mean-around-median ("mediam") — Xie et al. 2018, "Generalized
Byzantine-tolerant SGD" (the paper's companion).

Per coordinate: take the coordinate-wise median as the center, keep the
(m - b) values nearest to it, and average them.  Structurally Phocas
(Definition 8) with the median replacing the b-trimmed mean as the center —
the same dimensional resilience class, one fewer tunable (the median needs
no trim parameter), slightly looser variance constant.

This module is the single-file plugin template: the class below plus its
``@register_rule`` decoration is ALL that is needed for the rule to appear
in ``get_aggregator``, the train CLI, the fig2/fig3 sweeps, and the
registry round-trip tests.  Being a trim-family rule, it subclasses the
shared ``_TrimFamilyRule`` plumbing so the median center, the
nearest-(m-b) window, the drop-count scores, and the defense gate's median
row all come from ONE shared selection pass (``core/selection.py``,
DESIGN.md §8) instead of the two sorts the pre-fusion implementation paid.
"""
from __future__ import annotations

import jax

from repro.core import selection
from repro.core.aggregators import _TrimFamilyRule
from repro.core.registry import register_rule


@register_rule
class MeanAroundMedian(_TrimFamilyRule):
    name = "mediam"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    emits_scores = True
    trim_kind = "mediam"

    def _baseline(self, m: int) -> float:
        # benign baseline: each coordinate drops the b farthest of m values
        return float(self.params.b) / m

    def _reduce_xla(self, u: jax.Array) -> jax.Array:
        return selection.trim_family(u, self.params.b, "mediam")[0]
