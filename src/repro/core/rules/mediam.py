"""Mean-around-median ("mediam") — Xie et al. 2018, "Generalized
Byzantine-tolerant SGD" (the paper's companion).

Per coordinate: take the coordinate-wise median as the center, keep the
(m - b) values nearest to it, and average them.  Structurally Phocas
(Definition 8) with the median replacing the b-trimmed mean as the center —
the same dimensional resilience class, one fewer tunable (the median needs
no trim parameter), slightly looser variance constant.

This module is the single-file plugin template: the class below plus its
``@register_rule`` decoration is ALL that is needed for the rule to appear
in ``get_aggregator``, the train CLI, the fig2/fig3 sweeps, and the
registry round-trip tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import AggregatorRule, register_rule


@register_rule
class MeanAroundMedian(AggregatorRule):
    name = "mediam"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True

    def _reduce_xla(self, u: jax.Array) -> jax.Array:
        m = u.shape[0]
        b = self.params.b
        if not 0 <= b <= (m + 1) // 2 - 1:
            raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")
        uf = u.astype(jnp.float32) if u.dtype != jnp.float32 else u
        if b == 0:
            return jnp.mean(uf, axis=0)
        center = jnp.median(uf, axis=0)
        dist = jnp.abs(uf - center[None])
        order = jnp.argsort(dist, axis=0)             # ascending distance
        ranks = jnp.argsort(order, axis=0)            # per-coordinate rank
        keep = (ranks < (m - b)).astype(uf.dtype)
        return jnp.sum(uf * keep, axis=0) / (m - b)
