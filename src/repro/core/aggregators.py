"""Robust gradient aggregation rules (the paper's core contribution).

Every rule consumes a worker-gradient matrix ``u`` of shape ``(m, d)`` (m
workers along axis 0) and returns the aggregated ``(d,)`` vector.  All rules
are pure ``jnp`` and jit/shard_map friendly; the coordinate-wise rules
(``trmean``, ``phocas``, ``median``, ``mean``) broadcast over any trailing
shape, so they can be applied directly to ``(m, *leaf_shape)`` pytree leaves.

Definitions follow the paper:

* ``trmean``  — Definition 7, b-trimmed coordinate-wise mean.
* ``phocas``  — Definition 8, mean of the (m-b) values nearest to the
  b-trimmed mean, per coordinate.
* ``krum`` / ``multikrum`` — Definition 3 / Blanchard et al. baselines.
* ``mean`` / ``median`` / ``geomedian`` — non-robust / Yin-et-al-family
  baselines.

Each rule is additionally registered with ``repro.core.registry`` as an
:class:`~repro.core.registry.AggregatorRule` subclass (bottom of this file);
the registry objects carry the metadata (coordinate-wise?, resilience class,
kernel availability) and the ``reduce_sharded`` collectives that the
distributed engine, CLI, and benchmarks dispatch on.  Further rules live as
single-file plugins under ``repro/core/rules/``.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.core.registry import (AggregatorRule, RuleParams,
                                 distance_ratio_scores,
                                 drop_frequency_scores, make_rule,
                                 register_rule)
from repro.core.selection import ncoords_of as _ncoords_of

Aggregator = Callable[..., jax.Array]


def _as_f32(u: jax.Array) -> jax.Array:
    return u.astype(jnp.float32) if u.dtype != jnp.float32 else u


# ---------------------------------------------------------------------------
# Coordinate-wise rules (all built on the shared selection pass, DESIGN.md §8)
# ---------------------------------------------------------------------------

def mean(u: jax.Array) -> jax.Array:
    """Plain averaging — the non-robust default (Proposition 1: NOT resilient)."""
    return jnp.mean(_as_f32(u), axis=0)


def median(u: jax.Array) -> jax.Array:
    """Coordinate-wise median (= trmean with maximal b for odd m)."""
    return selection.matrix_median(u)


def trmean(u: jax.Array, b: int) -> jax.Array:
    """Coordinate-wise b-trimmed mean (Definition 7): the average of the
    middle ``m - 2b`` order statistics of each coordinate."""
    return selection.trim_family(u, b, "trmean")[0]


def phocas(u: jax.Array, b: int) -> jax.Array:
    """Phocas (Definition 8): average of the (m-b) values nearest to the
    b-trimmed mean, per coordinate."""
    return selection.trim_family(u, b, "phocas")[0]


# ---------------------------------------------------------------------------
# Coordinate-wise selection statistics (defense suspicion signal)
# ---------------------------------------------------------------------------

def trmean_stats(u: jax.Array, b: int) -> Tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Trimmed mean + its selection mask: ``(agg, drop_counts, ncoords)``.

    ``drop_counts[i]`` = number of coordinates where worker i's value was
    among the b smallest or b largest (i.e. trimmed away), with stable-rank
    tie handling identical to the historical double-argsort mask.
    """
    return selection.trim_family(u, b, "trmean", with_scores=True)


def phocas_stats(u: jax.Array, b: int) -> Tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Phocas + its selection mask: ``(agg, drop_counts, ncoords)`` where
    ``drop_counts[i]`` counts coordinates where worker i was among the b
    values farthest from the trimmed mean (dropped by Definition 8)."""
    return selection.trim_family(u, b, "phocas", with_scores=True)


def trim_mask_scores(stats_fn, mat: jax.Array, b: int, baseline: float,
                     psum_axes: Sequence[str]):
    """Shared sharded-score plumbing for the trim-mask rules (used by the
    built-ins below AND plugin rules like ``rules/mediam.py``): compute the
    slice-local selection statistics via ``stats_fn(mat, b) -> (agg,
    drop_counts, ncoords)``, psum counts AND coordinate totals over
    ``psum_axes`` (dim-sharded worker axes + model axes), normalize."""
    from repro.dist.collectives import psum_axes as _psum
    agg, counts, ncoords = stats_fn(mat, b)
    axes = tuple(psum_axes)
    counts = _psum(counts, axes)
    ncoords = _psum(ncoords, axes)
    return agg, drop_frequency_scores(counts, ncoords, baseline)


def fused_trim_family_scores(mat: jax.Array, b: int, kind: str,
                             baseline: float,
                             active: Optional[jax.Array],
                             psum_axes: Sequence[str]):
    """One-pass defense path for the trim family (trmean/phocas/mediam):
    raw drop-count scores AND the reputation-gated aggregate from a single
    shared selection pass (``selection.trim_family``), then the standard
    psum-before-normalize score plumbing.  Backs the rules'
    ``reduce_sharded_gated_with_scores`` overrides."""
    return trim_mask_scores(
        lambda u, b_: selection.trim_family(u, b_, kind, active=active,
                                            with_scores=True),
        mat, b, baseline, psum_axes)


# ---------------------------------------------------------------------------
# Vector-wise (classic) rules — Krum family
# ---------------------------------------------------------------------------

def _pairwise_sq_dists(u: jax.Array) -> jax.Array:
    """(m, m) squared Euclidean distances via the Gram matrix (MXU friendly)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))
    sq = jnp.sum(uf * uf, axis=1)
    gram = uf @ uf.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def krum_scores(u: jax.Array, q: int) -> jax.Array:
    """Per-worker Krum score: sum of sq-distances to the m-q-2 nearest others."""
    m = u.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    d2 = _pairwise_sq_dists(u)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.sum(nearest, axis=1)


def krum(u: jax.Array, q: int) -> jax.Array:
    """Krum (Definition 3): the candidate with minimal score.

    NOT dimensional-Byzantine resilient (Proposition 3) — baseline only.
    """
    scores = krum_scores(u, q)
    idx = jnp.argmin(scores)
    return _as_f32(u.reshape(u.shape[0], -1))[idx].reshape(u.shape[1:])


def multikrum(u: jax.Array, q: int, k: int | None = None) -> jax.Array:
    """Multi-Krum: average the k lowest-score candidates (Blanchard et al.)."""
    m = u.shape[0]
    if k is None:
        k = m - q - 2
    scores = krum_scores(u, q)
    _, idx = jax.lax.top_k(-scores, k)
    flat = _as_f32(u.reshape(m, -1))
    return jnp.mean(flat[idx], axis=0).reshape(u.shape[1:])


def geomedian(u: jax.Array, iters: int = 8, eps: float = 1e-8) -> jax.Array:
    """Geometric median via Weiszfeld iterations (Chen et al. family baseline)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))
    z = geomedian_sharded(uf, (), iters=iters, eps=eps)
    return z.reshape(u.shape[1:])


# ---------------------------------------------------------------------------
# Sharded (inside-shard_map) statistics shared by the vector-wise rules
# ---------------------------------------------------------------------------

def krum_scores_sharded(mat: jax.Array, q: int,
                        psum_axes: Sequence[str]) -> jax.Array:
    """Krum scores on a dim-sharded (m, D_slice) matrix: Gram partial
    distances are psum'd over ``psum_axes`` so selection sees full-vector
    geometry (empty axes = the plain single-device computation)."""
    from repro.dist.collectives import psum_axes as _psum
    m = mat.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    sq = jnp.sum(mat * mat, axis=1)
    gram = mat @ mat.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    d2 = _psum(d2, tuple(psum_axes))
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.sum(nearest, axis=1)


def krum_gated_scores_sharded(mat: jax.Array, active: jax.Array, q: int,
                              psum_axes: Sequence[str]
                              ) -> Tuple[jax.Array, jax.Array]:
    """Raw AND reputation-gated Krum score sums from ONE Gram pass.

    The gated matrix A replaces ejected rows with the raw median row
    ``med`` (:func:`selection.gate_matrix`), so its pairwise squared
    distances are recoverable from the raw distances plus each row's
    distance to ``med``::

        d2_A(i, j) = a_i a_j d2(i, j) + a_i (1 - a_j) e_i
                                      + (1 - a_i) a_j e_j

    where ``e_i = ||mat_i - med||^2`` (and both-ejected pairs are 0).
    That turns the defense path's second O(m^2 d) Gram into an O(m d)
    correction — the one-pass ``fused_gate`` route the registry metadata
    advertises — and both score vectors share one collective: ``d2`` and
    ``e`` psum together as a single (m+1, m) block.
    """
    from repro.dist.collectives import psum_axes as _psum
    m = mat.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    sq = jnp.sum(mat * mat, axis=1)
    gram = mat @ mat.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    med = selection.matrix_median(mat)
    e = jnp.sum((mat - med[None]) ** 2, axis=1)
    block = _psum(jnp.concatenate([d2, e[None, :]], axis=0),
                  tuple(psum_axes))
    d2, e = block[:m], block[m]
    a = active.astype(d2.dtype)
    d2_gated = (a[:, None] * a[None, :] * d2
                + a[:, None] * (1.0 - a[None, :]) * e[:, None]
                + (1.0 - a[:, None]) * a[None, :] * e[None, :])
    inf_diag = jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    raw = jnp.sum(jnp.sort(d2 + inf_diag, axis=1)[:, :k], axis=1)
    gated = jnp.sum(jnp.sort(d2_gated + inf_diag, axis=1)[:, :k], axis=1)
    return raw, gated


# Pre-Weiszfeld row clipping: rows whose norm exceeds this multiple of the
# median row norm are rescaled onto that cap.  Under the omniscient attack's
# 1e20 blow-up the un-clipped fixed point cannot localize in a small fixed
# iteration budget (every finite-precision weight underflows against a 1e20
# row), which destroyed the rule's suspicion scores; benign rows share the
# median's norm scale to within small factors, so a generous cap leaves
# clean runs bit-identical (ROADMAP item d).
WEISZFELD_CLIP_FACTOR = 4.0


def clip_rows_to_norm_quantile(mat: jax.Array, psum_axes: Sequence[str],
                               factor: float = WEISZFELD_CLIP_FACTOR,
                               eps: float = 1e-12) -> jax.Array:
    """Rescale rows of a (possibly dim-sharded) (m, D_slice) matrix so no
    row's full-vector norm exceeds ``factor`` x the median row norm."""
    from repro.dist.collectives import psum_axes as _psum
    sq = _psum(jnp.sum(mat * mat, axis=1), tuple(psum_axes))
    norms = jnp.sqrt(sq)
    cap = factor * jnp.median(norms)
    # A zero median norm (most rows exactly zero) carries no scale
    # information — leave the matrix untouched rather than clip to zero.
    scale = jnp.where(cap > 0.0,
                      jnp.minimum(1.0, cap / jnp.maximum(norms, eps)), 1.0)
    return mat * scale[:, None]


def geomedian_sharded(mat: jax.Array, psum_axes: Sequence[str],
                      iters: int = 8, eps: float = 1e-8,
                      with_dists: bool = False):
    """Weiszfeld iterations on a dim-sharded (m, D_slice) matrix: partial
    squared distances are psum'd over ``psum_axes`` so weights use the full
    vector geometry while updates stay slice-local.  Rows are norm-clipped
    to a robust quantile first so a 1e20 adversarial row cannot stall the
    fixed point (see :func:`clip_rows_to_norm_quantile`).

    With ``with_dists=True`` also returns each worker's full-vector
    distance to the final iterate (psum'd — the inverse of the Weiszfeld
    weight, the rule's per-worker suspicion statistic)."""
    from repro.dist.collectives import psum_axes as _psum
    mat = clip_rows_to_norm_quantile(mat, psum_axes)

    def step(z, _):
        d2 = jnp.sum((mat - z[None]) ** 2, axis=1)
        d2 = _psum(d2, tuple(psum_axes))
        w = 1.0 / jnp.maximum(jnp.sqrt(d2), eps)
        z_new = jnp.sum(mat * w[:, None], axis=0) / jnp.sum(w)
        return z_new, None

    z, _ = jax.lax.scan(step, jnp.mean(mat, axis=0), None, length=iters)
    if not with_dists:
        return z
    d2 = _psum(jnp.sum((mat - z[None]) ** 2, axis=1), tuple(psum_axes))
    return z, jnp.sqrt(d2)


# ---------------------------------------------------------------------------
# Registered rule objects (metadata + dispatch; math stays in the functions)
# ---------------------------------------------------------------------------

@register_rule
class MeanRule(AggregatorRule):
    """Plain averaging — NOT Byzantine resilient (Proposition 1)."""
    name = "mean"
    coordinate_wise = True
    resilience = "none"
    supports_streaming = True

    def _reduce_xla(self, u):
        return mean(u)


@register_rule
class MedianRule(AggregatorRule):
    """Coordinate-wise median — dimensional resilient (trmean, maximal b)."""
    name = "median"
    coordinate_wise = True
    resilience = "dimensional"

    def _reduce_xla(self, u):
        return median(u)


class _TrimFamilyRule(AggregatorRule):
    """Shared score/gate plumbing for the trim-family rules.

    Subclasses set ``trim_kind`` (a ``selection.trim_family`` kind) and
    ``_baseline(m)`` — the drop frequency an exchangeable benign worker
    expects, subtracted out by ``drop_frequency_scores``.  The fused
    defense path and the kernel-backed score path are identical across the
    family, so they live here once.
    """
    trim_kind: str = ""
    fused_gate = True

    def _baseline(self, m: int) -> float:
        raise NotImplementedError

    def _kernel_stats(self, u, b):
        """(agg, drop_counts, ncoords) via the rule's Pallas kernel."""
        raise NotImplementedError

    def _stats(self, u, b):
        if self.backend == "pallas":
            from repro.kernels.trmean.kernel import COUNTS_LANES
            if u.shape[0] <= COUNTS_LANES:
                return self._kernel_stats(u, b)
            # counts kernels pack m into one 128-lane output row; larger
            # fleets fall back to the XLA selection path rather than crash
        return selection.trim_family(u, b, self.trim_kind, with_scores=True)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        return trim_mask_scores(self._stats, mat, self.params.b,
                                self._baseline(mat.shape[0]), psum_axes)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        if self.backend == "pallas":
            # kernel path: counts from the score kernel, gated aggregate
            # from a second kernel launch (the base-class composition).
            return super().reduce_sharded_gated_with_scores(
                mat, active, psum_axes)
        return fused_trim_family_scores(mat, self.params.b, self.trim_kind,
                                        self._baseline(mat.shape[0]),
                                        active, psum_axes)


@register_rule
class TrmeanRule(_TrimFamilyRule):
    """b-trimmed coordinate-wise mean (Definition 7)."""
    name = "trmean"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    has_kernel = True
    supports_streaming = True
    emits_scores = True
    trim_kind = "trmean"

    def _baseline(self, m: int) -> float:
        # benign baseline: each coordinate trims exactly 2b of m values
        return 2.0 * self.params.b / m

    def _reduce_xla(self, u):
        return trmean(u, self.params.b)

    def _reduce_pallas(self, u):
        from repro.kernels.trmean.ops import trmean as ktrmean
        return ktrmean(u, self.params.b)

    def _kernel_stats(self, u, b):
        from repro.kernels.trmean.ops import trmean_with_counts
        agg, counts = trmean_with_counts(u.reshape(u.shape[0], -1), b)
        return agg.reshape(u.shape[1:]), counts, _ncoords_of(u)


@register_rule
class PhocasRule(_TrimFamilyRule):
    """Phocas (Definition 8)."""
    name = "phocas"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    has_kernel = True
    supports_streaming = True
    emits_scores = True
    trim_kind = "phocas"

    def _baseline(self, m: int) -> float:
        # benign baseline: each coordinate drops the b farthest of m values
        return float(self.params.b) / m

    def _reduce_xla(self, u):
        return phocas(u, self.params.b)

    def _reduce_pallas(self, u):
        from repro.kernels.phocas.ops import phocas as kphocas
        return kphocas(u, self.params.b)

    def _kernel_stats(self, u, b):
        from repro.kernels.phocas.ops import phocas_with_counts
        agg, counts = phocas_with_counts(u.reshape(u.shape[0], -1), b)
        return agg.reshape(u.shape[1:]), counts, _ncoords_of(u)


@register_rule
class KrumRule(AggregatorRule):
    """Krum (Definition 3) — classic resilience only (Proposition 3)."""
    name = "krum"
    coordinate_wise = False
    resilience = "classic"
    uses_q = True
    has_kernel = True
    emits_scores = True
    fused_gate = True

    def _reduce_xla(self, u):
        return krum(u, self.params.q)

    def _reduce_pallas(self, u):
        from repro.kernels.krum.ops import krum as kkrum
        return kkrum(u, self.params.q)

    def reduce_sharded(self, mat, psum_axes):
        scores = krum_scores_sharded(mat, self.params.q, psum_axes)
        return mat[jnp.argmin(scores)]

    def reduce_sharded_with_scores(self, mat, psum_axes):
        raw = krum_scores_sharded(mat, self.params.q, psum_axes)
        return mat[jnp.argmin(raw)], distance_ratio_scores(raw)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        if active is None:
            return self.reduce_sharded_with_scores(mat, psum_axes)
        raw, gated = krum_gated_scores_sharded(mat, active, self.params.q,
                                               psum_axes)
        pick = selection.gate_matrix(mat, active)[jnp.argmin(gated)]
        return pick, distance_ratio_scores(raw)


@register_rule
class MultikrumRule(AggregatorRule):
    """Multi-Krum: mean of the k lowest-score candidates."""
    name = "multikrum"
    coordinate_wise = False
    resilience = "classic"
    uses_q = True
    has_kernel = True
    emits_scores = True
    fused_gate = True

    def _k(self, m: int) -> int:
        k = self.params.multikrum_k
        return m - self.params.q - 2 if k is None else k

    def _reduce_xla(self, u):
        return multikrum(u, self.params.q, self.params.multikrum_k)

    def _reduce_pallas(self, u):
        from repro.kernels.krum.ops import multikrum as kmultikrum
        return kmultikrum(u, self.params.q, self.params.multikrum_k)

    def reduce_sharded(self, mat, psum_axes):
        scores = krum_scores_sharded(mat, self.params.q, psum_axes)
        _, idx = jax.lax.top_k(-scores, self._k(mat.shape[0]))
        return jnp.mean(mat[idx], axis=0)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        raw = krum_scores_sharded(mat, self.params.q, psum_axes)
        _, idx = jax.lax.top_k(-raw, self._k(mat.shape[0]))
        return jnp.mean(mat[idx], axis=0), distance_ratio_scores(raw)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        if active is None:
            return self.reduce_sharded_with_scores(mat, psum_axes)
        raw, gated = krum_gated_scores_sharded(mat, active, self.params.q,
                                               psum_axes)
        _, idx = jax.lax.top_k(-gated, self._k(mat.shape[0]))
        agg = jnp.mean(selection.gate_matrix(mat, active)[idx], axis=0)
        return agg, distance_ratio_scores(raw)


@register_rule
class GeomedianRule(AggregatorRule):
    """Geometric median (Weiszfeld) — Chen et al. family baseline."""
    name = "geomedian"
    coordinate_wise = False
    resilience = "classic"
    emits_scores = True
    fused_gate = True

    def _reduce_xla(self, u):
        return geomedian(u, iters=self.params.geomedian_iters)

    def reduce_sharded(self, mat, psum_axes):
        return geomedian_sharded(mat, psum_axes,
                                 iters=self.params.geomedian_iters)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        # Weiszfeld weight = 1/distance: far (down-weighted) == suspicious.
        z, dists = geomedian_sharded(mat, psum_axes,
                                     iters=self.params.geomedian_iters,
                                     with_dists=True)
        return z, distance_ratio_scores(dists)

    def reduce_sharded_gated_with_scores(self, mat, active, psum_axes):
        """One Weiszfeld run instead of the composed path's two.

        The center comes from the gated matrix; the scores are the RAW
        rows' distances to that defended center (the flap-prevention
        invariant — scores observe raw submissions — holds, measured
        against the center the update actually uses).
        """
        if active is None:
            return self.reduce_sharded_with_scores(mat, psum_axes)
        from repro.dist.collectives import psum_axes as _psum
        z = geomedian_sharded(selection.gate_matrix(mat, active), psum_axes,
                              iters=self.params.geomedian_iters)
        d2 = _psum(jnp.sum((mat - z[None]) ** 2, axis=1), tuple(psum_axes))
        return z, distance_ratio_scores(jnp.sqrt(d2))


# ---------------------------------------------------------------------------
# Name-based lookup (registry-backed)
# ---------------------------------------------------------------------------

def get_aggregator(name: str, *, b: int = 0, q: int = 0,
                   multikrum_k: int | None = None,
                   geomedian_iters: int = 8,
                   backend: str = "xla") -> Aggregator:
    """Return a unary ``(m, ...) -> (...)`` aggregation closure by name.

    Thin compatibility wrapper over the registry: any rule registered via
    ``@register_rule`` (including single-file plugins) resolves here.
    Defaults to the pure-jnp path (this wrapper predates kernel dispatch and
    its callers are reference/validation code); pass ``backend="auto"`` or
    ``"pallas"`` to opt into declared kernels.
    """
    rule = make_rule(name, RuleParams(b=b, q=q, multikrum_k=multikrum_k,
                                      geomedian_iters=geomedian_iters,
                                      backend=backend))
    return rule.reduce


# Deprecated: static snapshots kept for backwards compatibility.  The source
# of truth is the registry (registry.coordinate_wise_rules() / ...), which
# also covers plugin rules.
COORDINATE_WISE = frozenset({"mean", "median", "trmean", "phocas"})
VECTOR_WISE = frozenset({"krum", "multikrum", "geomedian"})
