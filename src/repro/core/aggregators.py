"""Robust gradient aggregation rules (the paper's core contribution).

Every rule consumes a worker-gradient matrix ``u`` of shape ``(m, d)`` (m
workers along axis 0) and returns the aggregated ``(d,)`` vector.  All rules
are pure ``jnp`` and jit/shard_map friendly; the coordinate-wise rules
(``trmean``, ``phocas``, ``median``, ``mean``) broadcast over any trailing
shape, so they can be applied directly to ``(m, *leaf_shape)`` pytree leaves.

Definitions follow the paper:

* ``trmean``  — Definition 7, b-trimmed coordinate-wise mean.
* ``phocas``  — Definition 8, mean of the (m-b) values nearest to the
  b-trimmed mean, per coordinate.
* ``krum`` / ``multikrum`` — Definition 3 / Blanchard et al. baselines.
* ``mean`` / ``median`` / ``geomedian`` — non-robust / Yin-et-al-family
  baselines.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Aggregator = Callable[..., jax.Array]


def _as_f32(u: jax.Array) -> jax.Array:
    return u.astype(jnp.float32) if u.dtype != jnp.float32 else u


# ---------------------------------------------------------------------------
# Coordinate-wise rules
# ---------------------------------------------------------------------------

def mean(u: jax.Array) -> jax.Array:
    """Plain averaging — the non-robust default (Proposition 1: NOT resilient)."""
    return jnp.mean(_as_f32(u), axis=0)


def median(u: jax.Array) -> jax.Array:
    """Coordinate-wise median (= trmean with maximal b for odd m)."""
    return jnp.median(_as_f32(u), axis=0)


def trmean(u: jax.Array, b: int) -> jax.Array:
    """Coordinate-wise b-trimmed mean (Definition 7).

    Sorts each coordinate over the worker axis and averages the middle
    ``m - 2b`` order statistics.
    """
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")
    s = jnp.sort(_as_f32(u), axis=0)
    if b == 0:
        return jnp.mean(s, axis=0)
    return jnp.mean(s[b : m - b], axis=0)


def phocas(u: jax.Array, b: int) -> jax.Array:
    """Phocas (Definition 8): average of the (m-b) values nearest to the
    b-trimmed mean, per coordinate."""
    m = u.shape[0]
    uf = _as_f32(u)
    center = trmean(uf, b)
    if b == 0:
        return mean(uf)
    dist = jnp.abs(uf - center[None])
    # Keep the (m-b) nearest == drop the b farthest.  Implemented as a
    # top-k free masked sum: sort distances, threshold at the (m-b)-th.
    order = jnp.argsort(dist, axis=0)  # ascending distance
    ranks = jnp.argsort(order, axis=0)  # rank of each entry per coordinate
    keep = (ranks < (m - b)).astype(uf.dtype)
    return jnp.sum(uf * keep, axis=0) / (m - b)


# ---------------------------------------------------------------------------
# Vector-wise (classic) rules — Krum family
# ---------------------------------------------------------------------------

def _pairwise_sq_dists(u: jax.Array) -> jax.Array:
    """(m, m) squared Euclidean distances via the Gram matrix (MXU friendly)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))
    sq = jnp.sum(uf * uf, axis=1)
    gram = uf @ uf.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def krum_scores(u: jax.Array, q: int) -> jax.Array:
    """Per-worker Krum score: sum of sq-distances to the m-q-2 nearest others."""
    m = u.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    d2 = _pairwise_sq_dists(u)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.sum(nearest, axis=1)


def krum(u: jax.Array, q: int) -> jax.Array:
    """Krum (Definition 3): the candidate with minimal score.

    NOT dimensional-Byzantine resilient (Proposition 3) — baseline only.
    """
    scores = krum_scores(u, q)
    idx = jnp.argmin(scores)
    return _as_f32(u.reshape(u.shape[0], -1))[idx].reshape(u.shape[1:])


def multikrum(u: jax.Array, q: int, k: int | None = None) -> jax.Array:
    """Multi-Krum: average the k lowest-score candidates (Blanchard et al.)."""
    m = u.shape[0]
    if k is None:
        k = m - q - 2
    scores = krum_scores(u, q)
    _, idx = jax.lax.top_k(-scores, k)
    flat = _as_f32(u.reshape(m, -1))
    return jnp.mean(flat[idx], axis=0).reshape(u.shape[1:])


def geomedian(u: jax.Array, iters: int = 8, eps: float = 1e-8) -> jax.Array:
    """Geometric median via Weiszfeld iterations (Chen et al. family baseline)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))

    def step(z, _):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(uf - z[None], axis=1), eps)
        z_new = jnp.sum(uf * w[:, None], axis=0) / jnp.sum(w)
        return z_new, None

    z0 = jnp.mean(uf, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z.reshape(u.shape[1:])


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def get_aggregator(name: str, *, b: int = 0, q: int = 0,
                   multikrum_k: int | None = None) -> Aggregator:
    """Return a unary ``(m, ...) -> (...)`` aggregation closure by name."""
    name = name.lower()
    table: Dict[str, Aggregator] = {
        "mean": mean,
        "median": median,
        "trmean": functools.partial(trmean, b=b),
        "phocas": functools.partial(phocas, b=b),
        "krum": functools.partial(krum, q=q),
        "multikrum": functools.partial(multikrum, q=q, k=multikrum_k),
        "geomedian": geomedian,
    }
    if name not in table:
        raise ValueError(f"unknown aggregator {name!r}; have {sorted(table)}")
    return table[name]


COORDINATE_WISE = frozenset({"mean", "median", "trmean", "phocas"})
VECTOR_WISE = frozenset({"krum", "multikrum", "geomedian"})
