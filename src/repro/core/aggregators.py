"""Robust gradient aggregation rules (the paper's core contribution).

Every rule consumes a worker-gradient matrix ``u`` of shape ``(m, d)`` (m
workers along axis 0) and returns the aggregated ``(d,)`` vector.  All rules
are pure ``jnp`` and jit/shard_map friendly; the coordinate-wise rules
(``trmean``, ``phocas``, ``median``, ``mean``) broadcast over any trailing
shape, so they can be applied directly to ``(m, *leaf_shape)`` pytree leaves.

Definitions follow the paper:

* ``trmean``  — Definition 7, b-trimmed coordinate-wise mean.
* ``phocas``  — Definition 8, mean of the (m-b) values nearest to the
  b-trimmed mean, per coordinate.
* ``krum`` / ``multikrum`` — Definition 3 / Blanchard et al. baselines.
* ``mean`` / ``median`` / ``geomedian`` — non-robust / Yin-et-al-family
  baselines.

Each rule is additionally registered with ``repro.core.registry`` as an
:class:`~repro.core.registry.AggregatorRule` subclass (bottom of this file);
the registry objects carry the metadata (coordinate-wise?, resilience class,
kernel availability) and the ``reduce_sharded`` collectives that the
distributed engine, CLI, and benchmarks dispatch on.  Further rules live as
single-file plugins under ``repro/core/rules/``.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import (AggregatorRule, RuleParams,
                                 distance_ratio_scores,
                                 drop_frequency_scores, make_rule,
                                 register_rule)

Aggregator = Callable[..., jax.Array]


def _as_f32(u: jax.Array) -> jax.Array:
    return u.astype(jnp.float32) if u.dtype != jnp.float32 else u


# ---------------------------------------------------------------------------
# Coordinate-wise rules
# ---------------------------------------------------------------------------

def mean(u: jax.Array) -> jax.Array:
    """Plain averaging — the non-robust default (Proposition 1: NOT resilient)."""
    return jnp.mean(_as_f32(u), axis=0)


def median(u: jax.Array) -> jax.Array:
    """Coordinate-wise median (= trmean with maximal b for odd m)."""
    return jnp.median(_as_f32(u), axis=0)


def trmean(u: jax.Array, b: int) -> jax.Array:
    """Coordinate-wise b-trimmed mean (Definition 7).

    Sorts each coordinate over the worker axis and averages the middle
    ``m - 2b`` order statistics.
    """
    m = u.shape[0]
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range [0, ceil(m/2)-1] for m={m}")
    s = jnp.sort(_as_f32(u), axis=0)
    if b == 0:
        return jnp.mean(s, axis=0)
    return jnp.mean(s[b : m - b], axis=0)


def phocas(u: jax.Array, b: int) -> jax.Array:
    """Phocas (Definition 8): average of the (m-b) values nearest to the
    b-trimmed mean, per coordinate."""
    m = u.shape[0]
    uf = _as_f32(u)
    center = trmean(uf, b)
    if b == 0:
        return mean(uf)
    dist = jnp.abs(uf - center[None])
    # Keep the (m-b) nearest == drop the b farthest.  Implemented as a
    # top-k free masked sum: sort distances, threshold at the (m-b)-th.
    order = jnp.argsort(dist, axis=0)  # ascending distance
    ranks = jnp.argsort(order, axis=0)  # rank of each entry per coordinate
    keep = (ranks < (m - b)).astype(uf.dtype)
    return jnp.sum(uf * keep, axis=0) / (m - b)


# ---------------------------------------------------------------------------
# Coordinate-wise selection statistics (defense suspicion signal)
# ---------------------------------------------------------------------------

def _ncoords_of(u: jax.Array) -> jax.Array:
    """Static count of coordinates per worker (trailing-shape product)."""
    return jnp.float32(math.prod(u.shape[1:]) or 1)


def trmean_stats(u: jax.Array, b: int) -> Tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Trimmed mean + its selection mask: ``(agg, drop_counts, ncoords)``.

    ``drop_counts[i]`` = number of coordinates where worker i's value was
    among the b smallest or b largest (i.e. trimmed away).  The aggregate
    is :func:`trmean` itself (single source — the rank mask exists only
    for the counts; XLA CSEs the shared sort).
    """
    m = u.shape[0]
    uf = _as_f32(u)
    agg = trmean(uf, b)
    if b == 0:
        return agg, jnp.zeros((m,), jnp.float32), _ncoords_of(u)
    ranks = jnp.argsort(jnp.argsort(uf, axis=0), axis=0)
    dropped = (ranks < b) | (ranks >= m - b)
    counts = jnp.sum(dropped, axis=tuple(range(1, uf.ndim))
                     ).astype(jnp.float32)
    return agg, counts, _ncoords_of(u)


def phocas_stats(u: jax.Array, b: int) -> Tuple[jax.Array, jax.Array,
                                                jax.Array]:
    """Phocas + its selection mask: ``(agg, drop_counts, ncoords)`` where
    ``drop_counts[i]`` counts coordinates where worker i was among the b
    values farthest from the trimmed mean (dropped by Definition 8).  The
    aggregate is :func:`phocas` itself (single source — the rank mask
    exists only for the counts; XLA CSEs the shared center/distances)."""
    m = u.shape[0]
    uf = _as_f32(u)
    agg = phocas(uf, b)
    if b == 0:
        return agg, jnp.zeros((m,), jnp.float32), _ncoords_of(u)
    center = trmean(uf, b)
    dist = jnp.abs(uf - center[None])
    ranks = jnp.argsort(jnp.argsort(dist, axis=0), axis=0)
    dropped = ranks >= (m - b)
    counts = jnp.sum(dropped, axis=tuple(range(1, uf.ndim))
                     ).astype(jnp.float32)
    return agg, counts, _ncoords_of(u)


def trim_mask_scores(stats_fn, mat: jax.Array, b: int, baseline: float,
                     psum_axes: Sequence[str]):
    """Shared sharded-score plumbing for the trim-mask rules (used by the
    built-ins below AND plugin rules like ``rules/mediam.py``): compute the
    slice-local selection statistics via ``stats_fn(mat, b) -> (agg,
    drop_counts, ncoords)``, psum counts AND coordinate totals over
    ``psum_axes`` (dim-sharded worker axes + model axes), normalize."""
    from repro.dist.collectives import psum_axes as _psum
    agg, counts, ncoords = stats_fn(mat, b)
    axes = tuple(psum_axes)
    counts = _psum(counts, axes)
    ncoords = _psum(ncoords, axes)
    return agg, drop_frequency_scores(counts, ncoords, baseline)


# ---------------------------------------------------------------------------
# Vector-wise (classic) rules — Krum family
# ---------------------------------------------------------------------------

def _pairwise_sq_dists(u: jax.Array) -> jax.Array:
    """(m, m) squared Euclidean distances via the Gram matrix (MXU friendly)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))
    sq = jnp.sum(uf * uf, axis=1)
    gram = uf @ uf.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def krum_scores(u: jax.Array, q: int) -> jax.Array:
    """Per-worker Krum score: sum of sq-distances to the m-q-2 nearest others."""
    m = u.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    d2 = _pairwise_sq_dists(u)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.sum(nearest, axis=1)


def krum(u: jax.Array, q: int) -> jax.Array:
    """Krum (Definition 3): the candidate with minimal score.

    NOT dimensional-Byzantine resilient (Proposition 3) — baseline only.
    """
    scores = krum_scores(u, q)
    idx = jnp.argmin(scores)
    return _as_f32(u.reshape(u.shape[0], -1))[idx].reshape(u.shape[1:])


def multikrum(u: jax.Array, q: int, k: int | None = None) -> jax.Array:
    """Multi-Krum: average the k lowest-score candidates (Blanchard et al.)."""
    m = u.shape[0]
    if k is None:
        k = m - q - 2
    scores = krum_scores(u, q)
    _, idx = jax.lax.top_k(-scores, k)
    flat = _as_f32(u.reshape(m, -1))
    return jnp.mean(flat[idx], axis=0).reshape(u.shape[1:])


def geomedian(u: jax.Array, iters: int = 8, eps: float = 1e-8) -> jax.Array:
    """Geometric median via Weiszfeld iterations (Chen et al. family baseline)."""
    uf = _as_f32(u.reshape(u.shape[0], -1))

    def step(z, _):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(uf - z[None], axis=1), eps)
        z_new = jnp.sum(uf * w[:, None], axis=0) / jnp.sum(w)
        return z_new, None

    z0 = jnp.mean(uf, axis=0)
    z, _ = jax.lax.scan(step, z0, None, length=iters)
    return z.reshape(u.shape[1:])


# ---------------------------------------------------------------------------
# Sharded (inside-shard_map) statistics shared by the vector-wise rules
# ---------------------------------------------------------------------------

def krum_scores_sharded(mat: jax.Array, q: int,
                        psum_axes: Sequence[str]) -> jax.Array:
    """Krum scores on a dim-sharded (m, D_slice) matrix: Gram partial
    distances are psum'd over ``psum_axes`` so selection sees full-vector
    geometry (empty axes = the plain single-device computation)."""
    from repro.dist.collectives import psum_axes as _psum
    m = mat.shape[0]
    k = m - q - 2
    if k <= 0:
        raise ValueError(f"Krum requires m - q - 2 > 0 (m={m}, q={q})")
    sq = jnp.sum(mat * mat, axis=1)
    gram = mat @ mat.T
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    d2 = _psum(d2, tuple(psum_axes))
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.sum(nearest, axis=1)


def geomedian_sharded(mat: jax.Array, psum_axes: Sequence[str],
                      iters: int = 8, eps: float = 1e-8,
                      with_dists: bool = False):
    """Weiszfeld iterations on a dim-sharded (m, D_slice) matrix: partial
    squared distances are psum'd over ``psum_axes`` so weights use the full
    vector geometry while updates stay slice-local.

    With ``with_dists=True`` also returns each worker's full-vector
    distance to the final iterate (psum'd — the inverse of the Weiszfeld
    weight, the rule's per-worker suspicion statistic)."""
    from repro.dist.collectives import psum_axes as _psum

    def step(z, _):
        d2 = jnp.sum((mat - z[None]) ** 2, axis=1)
        d2 = _psum(d2, tuple(psum_axes))
        w = 1.0 / jnp.maximum(jnp.sqrt(d2), eps)
        z_new = jnp.sum(mat * w[:, None], axis=0) / jnp.sum(w)
        return z_new, None

    z, _ = jax.lax.scan(step, jnp.mean(mat, axis=0), None, length=iters)
    if not with_dists:
        return z
    d2 = _psum(jnp.sum((mat - z[None]) ** 2, axis=1), tuple(psum_axes))
    return z, jnp.sqrt(d2)


# ---------------------------------------------------------------------------
# Registered rule objects (metadata + dispatch; math stays in the functions)
# ---------------------------------------------------------------------------

@register_rule
class MeanRule(AggregatorRule):
    """Plain averaging — NOT Byzantine resilient (Proposition 1)."""
    name = "mean"
    coordinate_wise = True
    resilience = "none"
    supports_streaming = True

    def _reduce_xla(self, u):
        return mean(u)


@register_rule
class MedianRule(AggregatorRule):
    """Coordinate-wise median — dimensional resilient (trmean, maximal b)."""
    name = "median"
    coordinate_wise = True
    resilience = "dimensional"

    def _reduce_xla(self, u):
        return median(u)


@register_rule
class TrmeanRule(AggregatorRule):
    """b-trimmed coordinate-wise mean (Definition 7)."""
    name = "trmean"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    has_kernel = True
    supports_streaming = True
    emits_scores = True

    def _reduce_xla(self, u):
        return trmean(u, self.params.b)

    def _reduce_pallas(self, u):
        from repro.kernels.trmean.ops import trmean as ktrmean
        return ktrmean(u, self.params.b)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        # benign baseline: each coordinate trims exactly 2b of m values
        return trim_mask_scores(trmean_stats, mat, self.params.b,
                                 2.0 * self.params.b / mat.shape[0],
                                 psum_axes)


@register_rule
class PhocasRule(AggregatorRule):
    """Phocas (Definition 8)."""
    name = "phocas"
    coordinate_wise = True
    resilience = "dimensional"
    uses_b = True
    has_kernel = True
    supports_streaming = True
    emits_scores = True

    def _reduce_xla(self, u):
        return phocas(u, self.params.b)

    def _reduce_pallas(self, u):
        from repro.kernels.phocas.ops import phocas as kphocas
        return kphocas(u, self.params.b)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        # benign baseline: each coordinate drops the b farthest of m values
        return trim_mask_scores(phocas_stats, mat, self.params.b,
                                 float(self.params.b) / mat.shape[0],
                                 psum_axes)


@register_rule
class KrumRule(AggregatorRule):
    """Krum (Definition 3) — classic resilience only (Proposition 3)."""
    name = "krum"
    coordinate_wise = False
    resilience = "classic"
    uses_q = True
    has_kernel = True
    emits_scores = True

    def _reduce_xla(self, u):
        return krum(u, self.params.q)

    def _reduce_pallas(self, u):
        from repro.kernels.krum.ops import krum as kkrum
        return kkrum(u, self.params.q)

    def reduce_sharded(self, mat, psum_axes):
        scores = krum_scores_sharded(mat, self.params.q, psum_axes)
        return mat[jnp.argmin(scores)]

    def reduce_sharded_with_scores(self, mat, psum_axes):
        raw = krum_scores_sharded(mat, self.params.q, psum_axes)
        return mat[jnp.argmin(raw)], distance_ratio_scores(raw)


@register_rule
class MultikrumRule(AggregatorRule):
    """Multi-Krum: mean of the k lowest-score candidates."""
    name = "multikrum"
    coordinate_wise = False
    resilience = "classic"
    uses_q = True
    has_kernel = True
    emits_scores = True

    def _k(self, m: int) -> int:
        k = self.params.multikrum_k
        return m - self.params.q - 2 if k is None else k

    def _reduce_xla(self, u):
        return multikrum(u, self.params.q, self.params.multikrum_k)

    def _reduce_pallas(self, u):
        from repro.kernels.krum.ops import multikrum as kmultikrum
        return kmultikrum(u, self.params.q, self.params.multikrum_k)

    def reduce_sharded(self, mat, psum_axes):
        scores = krum_scores_sharded(mat, self.params.q, psum_axes)
        _, idx = jax.lax.top_k(-scores, self._k(mat.shape[0]))
        return jnp.mean(mat[idx], axis=0)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        raw = krum_scores_sharded(mat, self.params.q, psum_axes)
        _, idx = jax.lax.top_k(-raw, self._k(mat.shape[0]))
        return jnp.mean(mat[idx], axis=0), distance_ratio_scores(raw)


@register_rule
class GeomedianRule(AggregatorRule):
    """Geometric median (Weiszfeld) — Chen et al. family baseline."""
    name = "geomedian"
    coordinate_wise = False
    resilience = "classic"
    emits_scores = True

    def _reduce_xla(self, u):
        return geomedian(u, iters=self.params.geomedian_iters)

    def reduce_sharded(self, mat, psum_axes):
        return geomedian_sharded(mat, psum_axes,
                                 iters=self.params.geomedian_iters)

    def reduce_sharded_with_scores(self, mat, psum_axes):
        # Weiszfeld weight = 1/distance: far (down-weighted) == suspicious.
        z, dists = geomedian_sharded(mat, psum_axes,
                                     iters=self.params.geomedian_iters,
                                     with_dists=True)
        return z, distance_ratio_scores(dists)


# ---------------------------------------------------------------------------
# Name-based lookup (registry-backed)
# ---------------------------------------------------------------------------

def get_aggregator(name: str, *, b: int = 0, q: int = 0,
                   multikrum_k: int | None = None,
                   geomedian_iters: int = 8,
                   backend: str = "xla") -> Aggregator:
    """Return a unary ``(m, ...) -> (...)`` aggregation closure by name.

    Thin compatibility wrapper over the registry: any rule registered via
    ``@register_rule`` (including single-file plugins) resolves here.
    Defaults to the pure-jnp path (this wrapper predates kernel dispatch and
    its callers are reference/validation code); pass ``backend="auto"`` or
    ``"pallas"`` to opt into declared kernels.
    """
    rule = make_rule(name, RuleParams(b=b, q=q, multikrum_k=multikrum_k,
                                      geomedian_iters=geomedian_iters,
                                      backend=backend))
    return rule.reduce


# Deprecated: static snapshots kept for backwards compatibility.  The source
# of truth is the registry (registry.coordinate_wise_rules() / ...), which
# also covers plugin rules.
COORDINATE_WISE = frozenset({"mean", "median", "trmean", "phocas"})
VECTOR_WISE = frozenset({"krum", "multikrum", "geomedian"})
