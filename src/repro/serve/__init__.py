from repro.serve.cache import (BlockAllocator, OutOfBlocks,  # noqa: F401
                               PagedKVCache, DEFAULT_BLOCK_TOKENS)
from repro.serve.engine import (ServeEngine, batched_prefill_supported,  # noqa: F401
                                generate, generate_stepwise, make_serve_step,
                                shard_cache)
from repro.serve.robust_decode import (RobustDecoder,  # noqa: F401
                                       corrupt_replica, make_replicas)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
