from repro.serve.engine import make_serve_step, generate  # noqa: F401
