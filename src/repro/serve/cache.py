"""Paged KV cache: a global pool of fixed-size blocks + per-request block
tables (repro.serve v2, DESIGN.md §11).

The device side is the model's paged cache pytree (one ``(num_blocks,
block_tokens, Kv, hd)`` pool per layer, built by ``model.init_paged_cache``);
the host side is this module: a free-list :class:`BlockAllocator` and the
``(max_slots, max_blocks)`` int32 block tables the jitted paged decode step
gathers through.  Exact equivalence with the dense ring cache is a layout
argument, not an approximation: valid positions land at the same (position ->
k/v) mapping through the table indirection, masked positions contribute
exactly zero attention weight (tests/test_serve.py asserts bitwise equality).

Block 0 is the reserved null/trash block: it is never allocated, inactive
batch slots keep all-zero table rows that scatter their writes there, and any
unused table-tail entries gather it — always beyond the per-request validity
mask, so its (finite) garbage is weighted exactly 0.

``DEFAULT_BLOCK_TOKENS`` is a layout constant owned by this module
(PALLAS002): it must stay a multiple of the f32 TPU sublane (8) so a block's
token axis fills whole (8, 128) vector-memory tiles, and must divide
``kernels.common.DEFAULT_TILE_D`` so a lane-tile of flattened KV rows covers
whole blocks (CONTRACT009, checked live by ``repro.analysis.contracts``).
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import SUBLANE

# Tokens per KV block.  16 = 2 f32 sublanes: small enough that short prompts
# waste <1 block per request, large enough that the gather's block count
# stays modest at max_seq_len ~ few hundred.
DEFAULT_BLOCK_TOKENS = 16

assert DEFAULT_BLOCK_TOKENS % SUBLANE == 0, \
    "block token axis must fill whole TPU sublanes (CONTRACT009)"


class OutOfBlocks(RuntimeError):
    """The pool cannot cover an allocation; admission control should have
    prevented the request from entering the batch."""


class BlockAllocator:
    """Host-side free list over the global block pool.

    Block 0 is reserved (the null/trash block) and is never handed out;
    :meth:`free` refuses to take it back.  Allocation order is LIFO over a
    deterministic initial order, so identical request traces produce
    identical block tables — what makes the paged-vs-dense equivalence
    tests reproducible.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (block 0 is reserved), "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(
                f"requested {n} blocks, {len(self._free)} free "
                f"(pool size {self.num_blocks})")
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"cannot free block {b} (0 is reserved, "
                                 f"pool size {self.num_blocks})")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)


class PagedKVCache:
    """The serving engine's cache façade: device pool + host block tables.

    ``max_slots`` is the engine's concurrent-request capacity — one table
    row per slot.  ``num_blocks`` defaults to exactly covering every slot at
    ``max_seq_len`` (+ the reserved block 0), i.e. no oversubscription; pass
    a smaller pool to exercise admission control.
    """

    def __init__(self, model, *, max_slots: int, max_seq_len: int,
                 block_tokens: int = DEFAULT_BLOCK_TOKENS,
                 num_blocks: Optional[int] = None, replicas: int = 1):
        self.block_tokens = block_tokens
        self.max_blocks = -(-max_seq_len // block_tokens)
        if num_blocks is None:
            num_blocks = 1 + max_slots * self.max_blocks
        self.num_blocks = num_blocks
        if replicas > 1:
            # Replicated robust decode: each replica attends over its own
            # pool (its params differ, so its k/v differ); the block tables
            # are shared — one logical allocation per request.  A tuple of
            # independent pools, matching make_replicas' tuple layout.
            self.pool = tuple(model.init_paged_cache(num_blocks,
                                                     block_tokens)
                              for _ in range(replicas))
        else:
            self.pool = model.init_paged_cache(num_blocks, block_tokens)
        self.allocator = BlockAllocator(num_blocks)
        self.tables = np.zeros((max_slots, self.max_blocks), np.int32)
        self._owned: List[List[int]] = [[] for _ in range(max_slots)]

    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_tokens)

    def can_cover(self, tokens: int) -> bool:
        """Admission-control check: can a fresh request of ``tokens`` total
        length (prompt + max new tokens) be covered right now?"""
        return self.allocator.free_blocks >= self.blocks_for(tokens)

    def ensure(self, slot: int, tokens: int) -> None:
        """Grow ``slot``'s table to cover ``tokens`` positions (no-op when
        already covered).  Raises :class:`OutOfBlocks` when the pool can't."""
        need = self.blocks_for(tokens) - len(self._owned[slot])
        if need <= 0:
            return
        if self.blocks_for(tokens) > self.max_blocks:
            raise OutOfBlocks(
                f"request needs {self.blocks_for(tokens)} blocks but tables "
                f"hold max_blocks={self.max_blocks} (raise max_seq_len)")
        blocks = self.allocator.alloc(need)
        start = len(self._owned[slot])
        self._owned[slot].extend(blocks)
        self.tables[slot, start:start + need] = blocks

    def release(self, slot: int) -> None:
        """Free every block a finished request owned; the slot's table row
        returns to all-zeros (the null block) for the next occupant."""
        if self._owned[slot]:
            self.allocator.free(self._owned[slot])
            self._owned[slot] = []
        self.tables[slot, :] = 0

    def owned_blocks(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def device_tables(self) -> jnp.ndarray:
        """The full (max_slots, max_blocks) table as a device array — the
        jitted decode step's gather operand (fixed shape every step)."""
        return jnp.asarray(self.tables)
