"""Serving runtime: jitted single-token decode step + batched greedy
generation loop over the KV cache.

Multi-device serving reuses the ``repro.dist`` rules: parameters get the
tensor-parallel specs (``tree_pspecs``), the KV cache gets ``cache_pspec``
(request batch over the worker axes, GQA KV heads over the model axes), and
the decode step is traced under the mesh so ``shard_hint`` constraints
activate.  Single-device behavior (``mesh=None``) is unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding


def shard_cache(cache, mesh: Mesh):
    """Device-put a KV cache according to ``repro.dist.cache_pspec``."""
    from repro.dist.sharding import cache_pspec
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.device_put(
            leaf, NamedSharding(mesh, cache_pspec(path, leaf, mesh))),
        cache)


def make_serve_step(model, *, mesh: Optional[Mesh] = None, donate=True):
    """Returns ``serve_step(params, cache, tokens, pos) -> (next_tokens,
    logits, new_cache)`` — one new token per request against the cache."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], logits, cache

    jitted = jax.jit(serve_step, donate_argnums=(1,) if donate else ())
    if mesh is None:
        return jitted

    def stepped(params, cache, tokens, pos):
        with mesh:       # ambient mesh: activates shard_hint constraints
            return jitted(params, cache, tokens, pos)

    return stepped


def generate(model, params, prompts: jax.Array, max_new_tokens: int,
             *, max_len: Optional[int] = None,
             mesh: Optional[Mesh] = None):
    """Greedy batched generation.  prompts: (B, S0) int32.
    Prefills by stepping the prompt token-by-token (decode-path prefill),
    then samples greedily.  Returns (B, S0 + max_new_tokens).

    With ``mesh``, params and cache are laid out by the ``repro.dist``
    rules before the loop starts (requests shard over the worker axes)."""
    B, S0 = prompts.shape
    total = S0 + max_new_tokens if max_len is None else max_len
    cache = model.init_cache(B, total)
    if mesh is not None:
        from repro.train.step import shard_params
        params = shard_params(params, mesh)
        cache = shard_cache(cache, mesh)
    step = make_serve_step(model, mesh=mesh, donate=False)

    toks = prompts
    nxt = prompts[:, :1]
    for t in range(total - 1):
        cur = toks[:, t : t + 1] if t < S0 else nxt
        nxt, _, cache = step(params, cache, cur, jnp.int32(t))
        if t >= S0 - 1:
            toks = jnp.concatenate([toks, nxt], axis=1)
        if toks.shape[1] >= total:
            break
    return toks
