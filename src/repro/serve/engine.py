"""Serving runtime: jitted single-token decode step + batched greedy
generation loop over the KV cache."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh


def make_serve_step(model, *, mesh: Optional[Mesh] = None, donate=True):
    """Returns ``serve_step(params, cache, tokens, pos) -> (next_tokens,
    logits, new_cache)`` — one new token per request against the cache."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], logits, cache

    return jax.jit(serve_step, donate_argnums=(1,) if donate else ())


def generate(model, params, prompts: jax.Array, max_new_tokens: int,
             *, max_len: Optional[int] = None):
    """Greedy batched generation.  prompts: (B, S0) int32.
    Prefills by stepping the prompt token-by-token (decode-path prefill),
    then samples greedily.  Returns (B, S0 + max_new_tokens)."""
    B, S0 = prompts.shape
    total = S0 + max_new_tokens if max_len is None else max_len
    cache = model.init_cache(B, total)
    step = make_serve_step(model, donate=False)

    toks = prompts
    nxt = prompts[:, :1]
    for t in range(total - 1):
        cur = toks[:, t : t + 1] if t < S0 else nxt
        nxt, _, cache = step(params, cache, cur, jnp.int32(t))
        if t >= S0 - 1:
            toks = jnp.concatenate([toks, nxt], axis=1)
        if toks.shape[1] >= total:
            break
    return toks
