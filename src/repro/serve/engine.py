"""Serving runtime (repro.serve v2, DESIGN.md §11).

Two tiers:

* The **dense tier** (``make_serve_step`` / ``generate``) is the original
  static-batch greedy loop, now with a true batched prefill: one forward
  pass writes the whole prompt into the KV cache instead of stepping it
  token-by-token (the old loop survives as :func:`generate_stepwise`, the
  regression oracle).  Multi-device serving reuses the ``repro.dist`` rules
  unchanged.

* The **paged tier** (:class:`ServeEngine`) is the production path: paged
  KV cache with per-request block tables (``serve/cache.py``), continuous
  batching with admission control (``serve/scheduler.py``), and optional
  k-replica Byzantine-robust decode (``serve/robust_decode.py``).  Every
  decode step is ONE fixed-shape jitted call over all ``max_slots`` slots —
  inactive slots write to the reserved trash block and their outputs are
  ignored — so continuous join/retire never recompiles.  Prefills are
  grouped by prompt length and the group batch padded to a power of two,
  bounding compilation to O(log max_slots) shapes per prompt length.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.serve.cache import DEFAULT_BLOCK_TOKENS, PagedKVCache
from repro.serve.robust_decode import RobustDecoder
from repro.serve.scheduler import DECODE, Request, Scheduler


def shard_cache(cache, mesh: Mesh):
    """Device-put a KV cache according to ``repro.dist.cache_pspec``."""
    from repro.dist.sharding import cache_pspec
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.device_put(
            leaf, NamedSharding(mesh, cache_pspec(path, leaf, mesh))),
        cache)


def make_serve_step(model, *, mesh: Optional[Mesh] = None, donate=True):
    """Returns ``serve_step(params, cache, tokens, pos) -> (next_tokens,
    logits, new_cache)``.  With tokens (B,1)/scalar pos it is one decode
    step; with tokens (B,S0)/pos=arange(S0) it is a batched prefill whose
    next_tokens continue the prompt."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], logits, cache

    jitted = jax.jit(serve_step, donate_argnums=(1,) if donate else ())
    if mesh is None:
        return jitted

    def stepped(params, cache, tokens, pos):
        with mesh:       # ambient mesh: activates shard_hint constraints
            return jitted(params, cache, tokens, pos)

    return stepped


def batched_prefill_supported(cfg, prompt_len: int) -> bool:
    """Whether one decode_step call can prefill a (B, prompt_len) prompt:
    recurrent state (SSM/hybrid) steps by construction, enc-dec prefills in
    its own forward, and windowed ring buffers only hold prompt_len <= W."""
    if cfg.is_ssm or cfg.hybrid or cfg.is_encdec:
        return False
    return all(w is None or prompt_len <= w for w in cfg.layer_windows())


def generate(model, params, prompts: jax.Array, max_new_tokens: int,
             *, max_len: Optional[int] = None,
             mesh: Optional[Mesh] = None):
    """Greedy batched generation.  prompts: (B, S0) int32.  Prefills the
    whole prompt in ONE forward pass when the architecture allows it
    (falling back to the stepwise loop otherwise), then decodes greedily.
    Returns (B, S0 + max_new_tokens)."""
    B, S0 = prompts.shape
    total = S0 + max_new_tokens if max_len is None else max_len
    if not (S0 > 1 and batched_prefill_supported(model.cfg, S0)):
        return generate_stepwise(model, params, prompts, max_new_tokens,
                                 max_len=max_len, mesh=mesh)
    cache = model.init_cache(B, total)
    if mesh is not None:
        from repro.train.step import shard_params
        params = shard_params(params, mesh)
        cache = shard_cache(cache, mesh)
    step = make_serve_step(model, mesh=mesh, donate=False)

    toks = prompts
    nxt, _, cache = step(params, cache, prompts, jnp.arange(S0))
    toks = jnp.concatenate([toks, nxt], axis=1)
    t = S0
    while toks.shape[1] < total:
        nxt, _, cache = step(params, cache, nxt, jnp.int32(t))
        toks = jnp.concatenate([toks, nxt], axis=1)
        t += 1
    return toks


def generate_stepwise(model, params, prompts: jax.Array,
                      max_new_tokens: int, *, max_len: Optional[int] = None,
                      mesh: Optional[Mesh] = None):
    """The original decode-path prefill: step the prompt token-by-token.
    Kept as the fallback for architectures batched prefill cannot cover and
    as the regression oracle ``generate`` must match bit-for-bit."""
    B, S0 = prompts.shape
    total = S0 + max_new_tokens if max_len is None else max_len
    cache = model.init_cache(B, total)
    if mesh is not None:
        from repro.train.step import shard_params
        params = shard_params(params, mesh)
        cache = shard_cache(cache, mesh)
    step = make_serve_step(model, mesh=mesh, donate=False)

    toks = prompts
    nxt = prompts[:, :1]
    for t in range(total - 1):
        cur = toks[:, t : t + 1] if t < S0 else nxt
        nxt, _, cache = step(params, cache, cur, jnp.int32(t))
        if t >= S0 - 1:
            toks = jnp.concatenate([toks, nxt], axis=1)
        if toks.shape[1] >= total:
            break
    return toks


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ServeEngine:
    """Continuous-batching paged-cache serving engine.

    ``params`` is the model's params pytree — or, when ``decoder`` is given,
    the length-``decoder.k`` tuple of per-replica pytrees from
    ``robust_decode.make_replicas`` (corrupt replicas with
    ``corrupt_replica`` to test the defense; the tuple layout is a perf
    constraint, see make_replicas).  ``submit()`` enqueues requests; each
    ``step()`` retires
    finished requests, admits queued ones (slot + cache-footprint gates),
    prefills joiners, and runs one decode step over every active slot.
    ``run()`` loops until drained.
    """

    def __init__(self, model, params, *, max_slots: int = 8,
                 max_seq_len: int = 256,
                 block_tokens: int = DEFAULT_BLOCK_TOKENS,
                 num_blocks: Optional[int] = None,
                 decoder: Optional[RobustDecoder] = None,
                 telemetry=None):
        if not model.supports_paged:
            raise NotImplementedError(
                f"arch {model.cfg.name!r} is not paged-serving capable "
                "(see models.stack.paged_supported); use serve.generate")
        if decoder is not None and (not isinstance(params, tuple)
                                    or len(params) != decoder.k):
            raise ValueError(
                f"replicated decode needs params as a length-{decoder.k} "
                "tuple of per-replica pytrees (see "
                "robust_decode.make_replicas)")
        self.model = model
        self.params = params
        self.decoder = decoder
        # Any telemetry shape adapts onto the bus: a Recorder passes
        # through, a raw TelemetryWriter becomes its JSONL sink, None
        # becomes the shared disabled Recorder (every obs call a no-op).
        from repro.obs.metrics import as_recorder
        self.obs = as_recorder(telemetry)
        self.telemetry = telemetry
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.cache = PagedKVCache(
            model, max_slots=max_slots, max_seq_len=max_seq_len,
            block_tokens=block_tokens, num_blocks=num_blocks,
            replicas=decoder.k if decoder is not None else 1)
        self.pool = self.cache.pool
        self.scheduler = Scheduler(
            max_slots=max_slots,
            can_cover=self.cache.can_cover,
            reserve=self.cache.ensure,
            release=self.cache.release)
        self.steps_run = 0
        self._build_steps()

    # -- jitted device steps -------------------------------------------------

    def _build_steps(self):
        # The pool argument is DONATED in both jitted steps: every caller
        # threads self.pool through (the old buffers are dead after the
        # call), and in-place pool updates keep the k-replica decode step
        # within the perf guard's 3.5x-of-single budget.
        model = self.model
        if self.decoder is None:
            def prefill(params, pool, tokens, tables):
                logits, pool = model.prefill_paged(params, pool, tokens,
                                                   tables)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, pool

            def decode(params, pool, tokens, positions, tables, rep_state):
                logits, pool = model.decode_step_paged(
                    params, pool, tokens, positions, tables)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt, pool, rep_state, jnp.zeros((1,), jnp.float32)
        else:
            dec = self.decoder

            # params/pool are TUPLES of per-replica pytrees; the loops
            # unroll into k independent forwards inside one jitted program
            # (a stacked replica axis costs ~1.5x more — see make_replicas).
            def prefill(params, pool, tokens, tables):
                last, pools = [], []
                for p, c in zip(params, pool):
                    logits, nc = model.prefill_paged(p, c, tokens, tables)
                    last.append(logits[:, -1].astype(jnp.float32))
                    pools.append(nc)
                stacked = jnp.stack(last)                   # (k, B, V)
                k, B, V = stacked.shape
                # Aggregate through the current gate; reputation updates
                # stay on the homogeneous decode step (prefill batches are
                # partial and variable-shaped).
                agg, _ = dec.rule.reduce_gated_with_scores(
                    stacked.reshape(k, B * V), dec.rep_state["active"])
                nxt = jnp.argmax(agg.reshape(B, V), axis=-1).astype(jnp.int32)
                return nxt, tuple(pools)

            def decode(params, pool, tokens, positions, tables, rep_state):
                last, pools = [], []
                for p, c in zip(params, pool):
                    logits, nc = model.decode_step_paged(
                        p, c, tokens, positions, tables)
                    last.append(logits[:, -1])
                    pools.append(nc)
                agg, scores, new_state = dec.aggregate(
                    jnp.stack(last), rep_state)
                nxt = jnp.argmax(agg, axis=-1).astype(jnp.int32)
                return nxt, tuple(pools), new_state, scores

        self._prefill_fn = jax.jit(prefill, donate_argnums=(1,))
        self._decode_fn = jax.jit(decode, donate_argnums=(1,))

    # -- request API ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int) -> Request:
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if len(prompt) + max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"request needs {len(prompt) + max_new_tokens} positions, "
                f"engine max_seq_len={self.max_seq_len}")
        return self.scheduler.submit(prompt, max_new_tokens)

    # -- the loop --------------------------------------------------------------

    def step(self) -> int:
        """One engine iteration: retire -> admit -> prefill joiners -> one
        batched decode over every active slot.  Returns the number of
        tokens generated this step."""
        sched = self.scheduler
        obs = self.obs
        retired = sched.retire_finished()
        admitted = sched.admit()
        if retired:
            obs.count("serve_retired", len(retired))
        if admitted:
            obs.count("serve_admitted", len(admitted))
        # Admission-control save: slots are free but the queue head's cache
        # footprint doesn't fit — without the can_cover gate this step
        # would have raised OutOfBlocks mid-flight.
        if sched.queued and len(sched.active) < self.max_slots:
            obs.count("serve_outofblocks_averted")
        produced = 0

        # Batched prefill, grouped by prompt length (one compile per
        # (padded group size, prompt length) pair).
        by_len: dict = {}
        for req in admitted:
            by_len.setdefault(req.prompt_len, []).append(req)
        for S0, group in sorted(by_len.items()):
            tokens = np.zeros((_pow2(len(group)), S0), np.int32)
            tables = np.zeros((tokens.shape[0], self.cache.max_blocks),
                              np.int32)
            for i, req in enumerate(group):
                tokens[i] = req.prompt
                tables[i] = self.cache.tables[req.slot]
            with obs.span("prefill", step_num=self.steps_run,
                          prompt_len=S0, batch=tokens.shape[0]) as sp:
                nxt, self.pool = sp.sync(self._prefill_fn(
                    self.params, self.pool, jnp.asarray(tokens),
                    jnp.asarray(tables)))
            nxt = np.asarray(nxt)
            for i, req in enumerate(group):
                sched.mark_decoding(req, nxt[i])
                produced += 1

        # One fixed-shape decode step over all slots (inactive slots carry
        # zero tokens/positions and all-zero table rows -> trash block).
        decoding = [r for r in sched.active if r.state == DECODE
                    and not r.finished]
        if decoding:
            tokens = np.zeros((self.max_slots, 1), np.int32)
            positions = np.zeros((self.max_slots,), np.int32)
            for req in decoding:
                tokens[req.slot, 0] = req.generated[-1]
                positions[req.slot] = req.decode_pos
            rep = (self.decoder.rep_state if self.decoder is not None
                   else {})
            k = self.decoder.k if self.decoder is not None else 1
            with obs.span("decode", step_num=self.steps_run,
                          slots=len(decoding), k=k) as sp:
                nxt, self.pool, new_rep, scores = sp.sync(self._decode_fn(
                    self.params, self.pool, jnp.asarray(tokens),
                    jnp.asarray(positions), self.cache.device_tables(),
                    rep))
            nxt = np.asarray(nxt)
            for req in decoding:
                sched.append_token(req, nxt[req.slot])
                produced += 1
            if self.decoder is not None:
                self.decoder.observe(new_rep, scores,
                                     telemetry=obs,
                                     step=self.steps_run)
        obs.log("serve", self.steps_run, active=len(sched.active),
                queued=sched.queued, produced=produced,
                free_blocks=self.cache.allocator.free_blocks)
        self.steps_run += 1
        return produced

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Drive ``step()`` until every submitted request completed."""
        for _ in range(max_steps):
            if not self.scheduler.busy:
                break
            self.step()
        self.scheduler.retire_finished()
        return list(self.scheduler.completed)

    # -- measurement -----------------------------------------------------------

    def time_decode_step(self, iters: int = 20) -> float:
        """Median wall-time (ms) of the jitted all-slots decode call at the
        engine's current occupancy — the per-step cost BENCH_serve and the
        perf guard compare across single vs k-replica configurations.
        The pool is donated, so each iteration threads it like ``step()``
        does (idle slots write the trash block; contents are unchanged)."""
        import time
        tokens = jnp.zeros((self.max_slots, 1), jnp.int32)
        positions = jnp.zeros((self.max_slots,), jnp.int32)
        tables = self.cache.device_tables()
        rep = self.decoder.rep_state if self.decoder is not None else {}

        def once():
            nxt, self.pool, _, _ = self._decode_fn(
                self.params, self.pool, tokens, positions, tables, rep)
            jax.block_until_ready(nxt)

        once()                                                 # compile
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            once()
            samples.append((time.perf_counter() - t0) * 1e3)
        return float(np.median(samples))
