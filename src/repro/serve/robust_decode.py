"""Replicated Byzantine-robust decode (repro.serve v2, DESIGN.md §11).

The serving analogue of the paper's dimensional trimmed-mean guarantee: run
``k`` model replicas per decode step and aggregate their per-token logits
coordinate-wise through any registered :class:`AggregatorRule`, so a
corrupted replica (bit-rot, a poisoned checkpoint shard, a hijacked host)
cannot steer generation.  The rule's per-replica suspicion scores — the
detection framing of Fall of Empires (1903.03936) — feed the existing
``defense/reputation.py`` EMA state, so a *persistently* corrupted replica
is ejected from the aggregate (its rows replaced by the replica median via
the fused gate) and its health trajectory lands in the shared telemetry
JSONL.

The logits matrix (k, B, V) is flattened to (k, B·V) — each vocabulary
coordinate of each request is one aggregation coordinate, exactly the
worker-gradient layout the rules already handle, so phocas/trmean/mediam,
their Pallas kernels, and the fused gated path apply unchanged.

With two honest replicas among k=3 and b=1, trmean/phocas return the honest
logit *exactly* per coordinate (the corrupted value is trimmed whichever
side it lands on, leaving identical honest values), so robust greedy decode
matches clean greedy decode bitwise; plain ``mean`` diverges and — emitting
only uniform zero scores — never ejects (tests/test_serve.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import RuleParams, make_rule
from repro.defense.reputation import (DefenseConfig, init_reputation,
                                      update_reputation)


def make_replicas(params, k: int, *, key: Optional[jax.Array] = None,
                  jitter: float = 0.0) -> tuple:
    """``k`` copies of a params pytree, as a TUPLE of independent pytrees.

    A tuple — not a stacked leading axis — so the engine's replica loop
    unrolls over plain per-replica forwards: a stacked axis forces either
    batched gather/scatter (vmap) or a fresh full-params slice copy every
    decode step, both of which blow the <= 3.5x perf budget the guard pins.

    ``jitter > 0`` adds independent Gaussian perturbations of that relative
    scale per replica (cheap diversity — quantization-noise stand-in);
    ``jitter = 0`` gives identical replicas, the fault-tolerance
    configuration whose robust aggregate is exactly the clean value.
    """
    if jitter <= 0.0:
        return tuple(params for _ in range(k))
    if key is None:
        raise ValueError("jitter > 0 needs an explicit PRNG key")

    def noised(p, kk):
        leaves, treedef = jax.tree.flatten(p)
        keys = jax.random.split(kk, len(leaves))
        out = [x + jitter * jnp.std(x)
               * jax.random.normal(j, x.shape, jnp.float32).astype(x.dtype)
               for x, j in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out)

    return tuple(noised(params, kk) for kk in jax.random.split(key, k))


def corrupt_replica(replicas: tuple, index: int, key: jax.Array,
                    scale: float = 20.0) -> tuple:
    """Replace replica ``index``'s parameters with large Gaussian noise —
    the garbage-logits fault the robust-decode tests and benchmarks inject."""
    leaves, treedef = jax.tree.flatten(replicas[index])
    keys = jax.random.split(key, len(leaves))
    garbage = jax.tree.unflatten(treedef, [
        scale * jax.random.normal(kk, x.shape, jnp.float32).astype(x.dtype)
        for x, kk in zip(leaves, keys)])
    return tuple(garbage if i == index else r
                 for i, r in enumerate(replicas))


class RobustDecoder:
    """Aggregation + reputation policy for k-replica decode.

    Owns the rule instance and the mutable reputation state; the jit-traced
    math lives in :meth:`aggregate` (pure), the host-side state threading in
    :meth:`observe`.  The engine holds one of these and calls ``aggregate``
    inside its jitted decode step.
    """

    def __init__(self, rule: str = "phocas", k: int = 3,
                 b: Optional[int] = None,
                 defense: Optional[DefenseConfig] = None,
                 backend: str = "auto"):
        if k < 2:
            raise ValueError(f"replicated decode needs k >= 2, got {k}")
        bmax = (k + 1) // 2 - 1
        self.b = bmax if b is None else b
        if not 0 <= self.b <= bmax:
            raise ValueError(f"need 0 <= b <= (k+1)//2-1 = {bmax} for k={k} "
                             f"replicas, got b={self.b}")
        self.k = k
        self.rule_name = rule
        self.rule = make_rule(rule, RuleParams(b=self.b, q=self.b,
                                               backend=backend))
        self.defense = defense or DefenseConfig()
        self.rep_state = init_reputation(k)

    # -- jit-traced ----------------------------------------------------------

    def aggregate(self, logits: jax.Array, rep_state: dict
                  ) -> Tuple[jax.Array, jax.Array, dict]:
        """(k, B, V) per-replica logits -> ((B, V) aggregate, (k,) scores,
        updated reputation state).  Pure — called inside the engine's jitted
        decode step.  Scores observe the raw matrix; the aggregate reads the
        reputation-gated matrix (ejected replicas replaced by the median)."""
        k, B, V = logits.shape
        mat = logits.reshape(k, B * V).astype(jnp.float32)
        agg, scores = self.rule.reduce_gated_with_scores(
            mat, rep_state["active"])
        new_state = update_reputation(rep_state, scores, self.defense)
        return agg.reshape(B, V), scores, new_state

    # -- host-side -----------------------------------------------------------

    def observe(self, new_state: dict, scores, telemetry=None,
                step: int = 0) -> None:
        """Adopt the post-step reputation state; mirror it to the bus
        (per-step JSONL record + ejection/readmission counters on the
        active-mask transition)."""
        from repro.obs.metrics import as_recorder
        rec = as_recorder(telemetry)
        if rec.metrics_enabled:
            import numpy as np
            old = np.asarray(self.rep_state["active"])
            new = np.asarray(new_state["active"])
            ej = int(np.sum((old != 0) & (new == 0)))
            readmit = int(np.sum((old == 0) & (new != 0)))
            if ej:
                rec.count("ejections", ej, stream="robust_decode")
            if readmit:
                rec.count("readmissions", readmit, stream="robust_decode")
        self.rep_state = new_state
        rec.log("robust_decode", step,
                rule=self.rule_name, k=self.k, b=self.b,
                scores=scores,
                reputation=new_state["reputation"],
                active=new_state["active"])

    @property
    def active(self):
        return self.rep_state["active"]

    def ejected_replicas(self) -> list:
        import numpy as np
        return [int(i) for i, a in
                enumerate(np.asarray(self.rep_state["active"])) if a == 0.0]
