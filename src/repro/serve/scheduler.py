"""Continuous-batching scheduler: admission control + slot lifecycle
(repro.serve v2, DESIGN.md §11).

Requests move QUEUED -> PREFILL -> DECODE -> DONE.  The scheduler owns the
queue and the slot map; the engine owns the device step.  Admission is
two-gated: a free batch slot AND the paged cache able to cover the request's
*worst-case* footprint (prompt + max_new_tokens) — reserving up front means
a running request can never hit OutOfBlocks mid-decode, so there is no
preemption path to get wrong.

Joins and retires happen mid-loop between decode steps: ``admit()`` fills
free slots from the queue each engine step, ``retire()`` frees a finished
request's slot immediately, so the next ``admit()`` can reuse it — the
continuous-batching property the tests pin down.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

QUEUED, PREFILL, DECODE, DONE = "queued", "prefill", "decode", "done"


@dataclasses.dataclass
class Request:
    """One generation request and its measured lifecycle."""
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: str = QUEUED
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_budget(self) -> int:
        """Worst-case cache footprint in tokens (reserved at admission)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def decode_pos(self) -> int:
        """Cache position the next decode step writes — the last generated
        token's position (prefill wrote 0..prompt_len-1; generated token i
        sits at prompt_len+i).  Meaningful once prefill produced a token."""
        return self.prompt_len + len(self.generated) - 1

    @property
    def finished(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def latency_ms(self) -> float:
        return (self.t_done - self.t_enqueue) * 1e3

    def first_token_ms(self) -> float:
        return (self.t_first_token - self.t_enqueue) * 1e3


class Scheduler:
    """Admission-control queue over ``max_slots`` concurrent batch slots.

    ``can_cover(tokens)`` is the cache's admission gate (how many tokens of
    KV the pool can still reserve); ``reserve(slot, tokens)`` performs the
    reservation.  Both are injected so the scheduler stays a pure
    policy/bookkeeping object the tests can drive without a device.
    """

    def __init__(self, *, max_slots: int,
                 can_cover: Callable[[int], bool],
                 reserve: Callable[[int, int], None],
                 release: Callable[[int], None],
                 clock: Callable[[], float] = time.perf_counter):
        self.max_slots = max_slots
        self._can_cover = can_cover
        self._reserve = reserve
        self._release = release
        self._clock = clock
        self._queue: Deque[Request] = deque()
        self._slots: Dict[int, Request] = {}      # slot -> running request
        self._rid = itertools.count()
        self.completed: List[Request] = []

    # -- queue side ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=int(max_new_tokens),
                      t_enqueue=self._clock())
        self._queue.append(req)
        return req

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> List[Request]:
        return [self._slots[s] for s in sorted(self._slots)]

    @property
    def busy(self) -> bool:
        return bool(self._queue or self._slots)

    def slot_of(self, slot: int) -> Optional[Request]:
        return self._slots.get(slot)

    # -- engine side --------------------------------------------------------

    def admit(self) -> List[Request]:
        """Move queue heads into free slots while both gates pass.  FIFO —
        a too-big head blocks the queue rather than starving large requests
        behind small ones.  Admitted requests enter PREFILL with their full
        footprint reserved."""
        admitted: List[Request] = []
        free = sorted(set(range(self.max_slots)) - set(self._slots))
        while free and self._queue \
                and self._can_cover(self._queue[0].total_budget):
            req = self._queue.popleft()
            slot = free.pop(0)
            self._reserve(slot, req.total_budget)
            req.slot = slot
            req.state = PREFILL
            req.t_admitted = self._clock()
            self._slots[slot] = req
            admitted.append(req)
        return admitted

    def mark_decoding(self, req: Request, first_token: int) -> None:
        """Prefill produced the request's first generated token."""
        req.generated.append(int(first_token))
        req.t_first_token = self._clock()
        req.state = DECODE

    def append_token(self, req: Request, token: int) -> None:
        req.generated.append(int(token))

    def retire_finished(self) -> List[Request]:
        """Retire every request that hit its token budget: free the slot and
        its cache blocks so this step's ``admit()`` can reuse them."""
        done: List[Request] = []
        for slot in sorted(self._slots):
            req = self._slots[slot]
            if req.state == DECODE and req.finished:
                req.state = DONE
                req.t_done = self._clock()
                self._release(slot)
                del self._slots[slot]
                self.completed.append(req)
                done.append(req)
        return done
