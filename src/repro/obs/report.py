"""Reporter CLI: render a run summary from any telemetry JSONL file.

``python -m repro.obs.report run.jsonl`` reads the record stream any
instrumented path writes (trainer loops, ServeEngine, robust decode — all
through the same ``{"t", "kind", "step", ...}`` format) and prints:

* loss curve stats (first/last/min/mean) from train/streaming records,
* the ejection timeline — every step where a worker or replica flipped
  between active and ejected, reconstructed from consecutive ``active``
  masks,
* suspicion heat by worker (mean score, so a slowburn attacker's slow
  drift is visible even when it never crosses the ejection threshold),
* span latency stats (count / mean / p50 / p99 per span path — exact
  quantiles, since span records carry raw milliseconds),
* q̂ trajectory and close-time counter values (``metric`` records).

Pure-stdlib consumer: no jax import, so it runs on a laptop against a
JSONL scp'd out of a training cluster.
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Dict, List, Optional, Sequence


def _finite(values) -> List[float]:
    out = []
    for v in values:
        if isinstance(v, (int, float)) and not isinstance(v, bool) \
                and math.isfinite(v):
            out.append(float(v))
    return out


def _stats(values: List[float]) -> Optional[dict]:
    if not values:
        return None
    return {"first": values[0], "last": values[-1], "min": min(values),
            "max": max(values), "mean": sum(values) / len(values),
            "n": len(values)}


def _quantile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(math.ceil(q * len(sorted_vals))) - 1)
    return sorted_vals[max(0, idx)]


def _mask_transitions(records, label: str, timeline: List[dict]) -> None:
    """Append ejection/readmission events by diffing consecutive
    ``active`` masks within one record family."""
    prev = None
    for rec in records:
        active = rec.get("active")
        if not isinstance(active, (list, tuple)):
            continue
        if prev is not None and len(prev) == len(active):
            for i, (was, now) in enumerate(zip(prev, active)):
                if bool(was) != bool(now):
                    timeline.append({
                        "step": rec.get("step", -1), "who": i,
                        "stream": label,
                        "event": "ejected" if was else "readmitted"})
        prev = list(active)


def summarize(records: Sequence[dict]) -> dict:
    """Structured summary of one run's record stream."""
    by_kind: Dict[str, List[dict]] = {}
    for rec in records:
        by_kind.setdefault(rec.get("kind", "?"), []).append(rec)

    train = by_kind.get("train", []) + by_kind.get("streaming", [])
    train.sort(key=lambda r: r.get("step", 0))
    loss = _stats(_finite(r.get("loss") for r in train))

    timeline: List[dict] = []
    for label in ("train", "async", "robust_decode"):
        _mask_transitions(
            sorted(by_kind.get(label, []), key=lambda r: r.get("step", 0)),
            label, timeline)
    timeline.sort(key=lambda e: e["step"])

    # Suspicion heat: mean score per worker across defended records.
    sus_sum: Dict[int, float] = {}
    sus_n: Dict[int, int] = {}
    for rec in by_kind.get("train", []) + by_kind.get("async", []):
        scores = rec.get("suspicion")
        if isinstance(scores, (list, tuple)):
            for i, s in enumerate(scores):
                if isinstance(s, (int, float)) and math.isfinite(s):
                    sus_sum[i] = sus_sum.get(i, 0.0) + float(s)
                    sus_n[i] = sus_n.get(i, 0) + 1
    suspicion = {i: sus_sum[i] / sus_n[i] for i in sorted(sus_sum)}

    # Span latency: exact quantiles from the raw per-span milliseconds.
    span_ms: Dict[str, List[float]] = {}
    for rec in by_kind.get("span", []):
        ms = rec.get("ms")
        if isinstance(ms, (int, float)) and math.isfinite(ms):
            span_ms.setdefault(str(rec.get("name", "?")), []).append(
                float(ms))
    spans = {}
    for name, vals in sorted(span_ms.items()):
        vals.sort()
        spans[name] = {"n": len(vals), "mean": sum(vals) / len(vals),
                       "p50": _quantile(vals, 0.50),
                       "p99": _quantile(vals, 0.99)}

    q_hat = _stats(_finite(r.get("q_hat") for r in train
                           if r.get("q_hat") is not None))

    counters = {}
    for rec in by_kind.get("metric", []):
        if rec.get("type") == "counter":
            key = str(rec.get("name"))
            labels = rec.get("labels") or {}
            if labels:
                key += "{" + ",".join(f"{k}={v}" for k, v
                                      in sorted(labels.items())) + "}"
            counters[key] = rec.get("value")

    serve = by_kind.get("serve", [])
    produced = _finite(r.get("produced") for r in serve)

    return {
        "kinds": {k: len(v) for k, v in sorted(by_kind.items())},
        "loss": loss,
        "q_hat": q_hat,
        "ejections": timeline,
        "suspicion_by_worker": suspicion,
        "spans": spans,
        "counters": counters,
        "serve_tokens": sum(produced) if produced else None,
    }


def _fmt(v: float) -> str:
    return f"{v:.6g}"


def render(summary: dict) -> str:
    """Human-readable report for one summarize() result."""
    out: List[str] = []
    kinds = ", ".join(f"{k}={n}" for k, n in summary["kinds"].items())
    out.append(f"records: {kinds}")

    loss = summary["loss"]
    if loss:
        out.append(f"loss: first={_fmt(loss['first'])} "
                   f"last={_fmt(loss['last'])} min={_fmt(loss['min'])} "
                   f"mean={_fmt(loss['mean'])} (n={loss['n']})")
    q_hat = summary["q_hat"]
    if q_hat:
        out.append(f"q_hat: first={_fmt(q_hat['first'])} "
                   f"last={_fmt(q_hat['last'])} max={_fmt(q_hat['max'])}")

    if summary["ejections"]:
        out.append("ejection timeline:")
        for e in summary["ejections"]:
            out.append(f"  step {e['step']:>6}: worker {e['who']} "
                       f"{e['event']} ({e['stream']})")
    else:
        out.append("ejection timeline: none")

    if summary["suspicion_by_worker"]:
        out.append("suspicion heat (mean score by worker):")
        peak = max(summary["suspicion_by_worker"].values()) or 1.0
        for i, s in summary["suspicion_by_worker"].items():
            bar = "#" * int(round(20 * s / peak)) if peak > 0 else ""
            out.append(f"  worker {i:>3}: {_fmt(s):>10} {bar}")

    if summary["spans"]:
        out.append("span latency (ms):")
        out.append(f"  {'span':<32} {'n':>6} {'mean':>10} {'p50':>10} "
                   f"{'p99':>10}")
        for name, s in summary["spans"].items():
            out.append(f"  {name:<32} {s['n']:>6} {_fmt(s['mean']):>10} "
                       f"{_fmt(s['p50']):>10} {_fmt(s['p99']):>10}")

    if summary["counters"]:
        out.append("counters:")
        for name, v in sorted(summary["counters"].items()):
            out.append(f"  {name} = {_fmt(v)}")

    if summary["serve_tokens"] is not None:
        out.append(f"serve: {int(summary['serve_tokens'])} tokens produced")

    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run summary from a telemetry JSONL file.")
    parser.add_argument("jsonl", help="telemetry file written with "
                        "--telemetry / --metrics")
    parser.add_argument("--kind", default=None,
                        help="only summarize records of this kind")
    args = parser.parse_args(argv)

    from repro.defense.telemetry import read_jsonl
    records = read_jsonl(args.jsonl)
    if args.kind:
        records = [r for r in records if r.get("kind") == args.kind]
    if not records:
        print(f"no records in {args.jsonl}", file=sys.stderr)
        return 1
    print(render(summarize(records)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
