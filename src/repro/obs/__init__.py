"""repro.obs — unified tracing, metrics & profiling across train/serve/defense.

One :class:`Recorder` is threaded through every instrumented path (the
four Topology plugins, ServeEngine, RobustDecoder, the launch CLIs); it
fans records out to the legacy JSONL format, mirrors scalars into a
Prometheus-exportable metrics registry, and times spans under jax's async
dispatch.  See DESIGN.md §12 for the architecture.
"""
from repro.obs.metrics import (
    DEFAULT_MS_BUCKETS,
    DISABLED,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    ObsConfig,
    Recorder,
    as_recorder,
    make_recorder,
)
from repro.obs.schema import ENVELOPE, SCHEMA, check_kind, validate_record
from repro.obs.trace import NULL_SPAN, Span, set_default_recorder, span
from repro.obs.export import parse_exposition, render_prometheus, \
    write_snapshot
from repro.obs.profile import compiled_cost, device_memory_stats, \
    profile_trace

__all__ = [
    "DEFAULT_MS_BUCKETS", "DISABLED", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "ObsConfig", "Recorder", "as_recorder",
    "make_recorder", "ENVELOPE", "SCHEMA", "check_kind", "validate_record",
    "NULL_SPAN", "Span", "set_default_recorder", "span",
    "parse_exposition", "render_prometheus", "write_snapshot",
    "compiled_cost", "device_memory_stats", "profile_trace",
]
