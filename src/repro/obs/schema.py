"""Telemetry record-kind schema (the `kind` vocabulary of the JSONL bus).

Every record the observability bus emits — whether through the legacy
``TelemetryWriter.log`` sink or the :class:`repro.obs.Recorder` — carries a
``kind`` naming its record family.  ``SCHEMA`` is the registry of those
families: one entry per kind, mapping to the field names consumers may rely
on (advisory — a record may carry extra fields, but a consumer reading a
SCHEMA-listed field on a record of that kind gets a stable meaning).

The static-analysis rule CONTRACT010 (``repro/analysis/telemetry_kinds.py``)
pins every literal-kind ``.log(...)``/``.emit(...)`` call site in the repo to
this registry, so a typo'd kind fails the analysis gate instead of silently
forking the record stream.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List

# kind -> well-known fields (beyond the envelope keys "t"/"kind"/"step").
SCHEMA: Dict[str, FrozenSet[str]] = {
    # one sync-PS defended train step (topologies.SyncPS)
    "train": frozenset({"loss", "grad_norm", "suspicion", "reputation",
                        "active", "q_hat"}),
    # one buffered-async step (topologies.AsyncPS)
    "async": frozenset({"staleness_frac", "suspicion", "reputation",
                        "active", "q_hat"}),
    # one streaming-scan step (topologies.Streaming)
    "streaming": frozenset({"loss", "suspicion"}),
    # adapt_b fired: the online q-hat re-tuned the rule (topologies.SyncPS)
    "adapt": frozenset({"b", "q", "q_hat"}),
    # one ServeEngine iteration (queue depth / throughput)
    "serve": frozenset({"active", "queued", "produced", "free_blocks",
                        "admitted", "retired", "arch", "batch",
                        "prompt_len", "new_tokens", "wall_s", "tok_s",
                        "mesh"}),
    # one batched decode call (reserved for decode-step-level records)
    "decode": frozenset({"tokens", "slots", "ms"}),
    # per-step replicated robust-decode defense state (RobustDecoder)
    "robust_decode": frozenset({"rule", "k", "b", "scores", "reputation",
                                "active"}),
    # a point-in-time metric sample (Recorder close-time registry dump)
    "metric": frozenset({"name", "value", "labels", "type"}),
    # one timed span (Recorder.span with tracing enabled)
    "span": frozenset({"name", "ms", "labels"}),
    # a repro.analysis finding (python -m repro.analysis --jsonl)
    "analysis": frozenset({"rule", "severity", "path", "line", "message",
                           "hint"}),
}

# Envelope keys every record carries (written by the sink, not the caller).
ENVELOPE = ("t", "kind", "step")


def check_kind(kind: str) -> str:
    """Validate a record kind against the registry; returns it unchanged."""
    if kind not in SCHEMA:
        raise ValueError(
            f"unregistered telemetry kind {kind!r}; known kinds: "
            f"{', '.join(sorted(SCHEMA))} (register new kinds in "
            "repro/obs/schema.py)")
    return kind


def validate_record(rec: dict) -> List[str]:
    """Problems with one decoded JSONL record (empty list = valid).

    Checks the envelope (``t``/``kind``/``step`` present, kind registered)
    — the per-kind field sets are advisory, so extra or missing payload
    fields are NOT errors.
    """
    problems = []
    for key in ENVELOPE:
        if key not in rec:
            problems.append(f"missing envelope key {key!r}")
    kind = rec.get("kind")
    if kind is not None and kind not in SCHEMA:
        problems.append(f"unregistered kind {kind!r}")
    return problems
