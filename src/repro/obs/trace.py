"""Span-based tracer (repro.obs, DESIGN.md §12).

Spans are host-side wall-clock intervals with nesting: entering a span
pushes its name onto a thread-local stack, so a span opened inside another
records under the joined path (``"engine_step/decode"``), and the closed
span lands in the Recorder's ``span_ms`` histogram (labeled by path) plus —
when a JSONL sink is attached — as one ``kind="span"`` record.

**Async-dispatch contract.**  jax dispatches asynchronously: the Python
call that launches a jitted step returns before the device finishes, so a
naive ``perf_counter`` pair around it times the *dispatch*, not the work.
A span therefore exposes :meth:`Span.sync`: pass it the step's output and
it calls ``jax.block_until_ready`` **only when tracing is enabled** —
instrumented loops stay fully async in production (the no-op span's
``sync`` is identity, costs one attribute lookup, allocates nothing).

When ``jax.profiler`` is importable, an enabled span also enters a
``TraceAnnotation`` (``StepTraceAnnotation`` when ``step_num`` is given),
so the same spans show up as named regions in a real profiler trace
captured via ``obs/profile.py``'s ``--profile-dir`` window.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

_tls = threading.local()


def _stack() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def current_path() -> str:
    """The active span path ("" outside any span) — test/debug hook."""
    return "/".join(_stack())


class _NullSpan:
    """The shared zero-cost span: returned for every ``span()`` call while
    tracing is off.  A singleton so disabled instrumentation allocates
    nothing per call (pinned by tests/test_obs.py)."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    @staticmethod
    def sync(x):
        return x


NULL_SPAN = _NullSpan()


def _profiler_annotation(name: str, step_num: Optional[int]):
    """A jax.profiler annotation context for this span, or None when the
    profiler API is unavailable (older jax, stripped builds)."""
    try:
        from jax import profiler
        if step_num is not None and hasattr(profiler,
                                            "StepTraceAnnotation"):
            return profiler.StepTraceAnnotation(name, step_num=step_num)
        if hasattr(profiler, "TraceAnnotation"):
            return profiler.TraceAnnotation(name)
    except ImportError:
        pass
    return None


class Span:
    """One enabled timed span; create via ``Recorder.span(name, ...)``."""
    __slots__ = ("_recorder", "name", "labels", "step_num", "path",
                 "_t0", "_annotation")

    def __init__(self, recorder, name: str, labels: Dict[str, object],
                 step_num: Optional[int] = None):
        self._recorder = recorder
        self.name = name
        self.labels = labels
        self.step_num = step_num
        self.path = name
        self._t0 = 0.0
        self._annotation = None

    def __enter__(self) -> "Span":
        stack = _stack()
        stack.append(self.name)
        self.path = "/".join(stack)
        self._annotation = _profiler_annotation(self.name, self.step_num)
        if self._annotation is not None:
            self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def sync(self, x):
        """Block until ``x``'s device work is done (tracing is on, so the
        span should time the computation, not the dispatch).  Returns
        ``x`` so call sites can wrap the step expression in place."""
        import jax
        jax.block_until_ready(x)
        return x

    def __exit__(self, *exc) -> bool:
        ms = (time.perf_counter() - self._t0) * 1e3
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
            self._annotation = None
        stack = _stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        self._recorder._span_done(self.path, ms, self.labels,
                                  self.step_num)
        return False


# -- module-level convenience ------------------------------------------------

_default_recorder = None


def set_default_recorder(recorder) -> None:
    """Install the process-default Recorder :func:`span` binds to (None
    disarms it).  The launch CLIs set this so library code can open spans
    without threading the Recorder through every signature."""
    global _default_recorder
    _default_recorder = recorder


def span(name: str, step_num: Optional[int] = None, **labels):
    """A span on the process-default Recorder (no-op when none is set)."""
    if _default_recorder is None:
        return NULL_SPAN
    return _default_recorder.span(name, step_num=step_num, **labels)
