"""Prometheus-style text exposition for the obs metrics registry.

One snapshot of a :class:`repro.obs.metrics.MetricsRegistry`, rendered in
the Prometheus text format (version 0.0.4 subset): ``# TYPE`` header per
family, ``name{label="v"} value`` samples, histograms expanded into
cumulative ``_bucket{le="..."}`` series plus ``_sum``/``_count``.  All
series carry the ``repro_`` namespace prefix; names and label keys are
sanitised to the Prometheus charset so span paths like
``engine_step/decode`` survive as label *values* (quoted, escaped) while
never leaking illegal characters into metric names.

``parse_exposition`` is the matching reader — enough of a parser for the
CI smoke test and the golden-file test to assert "snapshot parses and the
core series are present" without a prometheus client dependency.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Tuple

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_SANITIZE = re.compile(r"[^a-zA-Z0-9_]")

PREFIX = "repro_"


def _metric_name(name: str) -> str:
    name = _SANITIZE.sub("_", name)
    if not _NAME_OK.match(name):
        name = "_" + name
    return PREFIX + name


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace("\"", "\\\"") \
                .replace("\n", "\\n")


def _render_labels(pairs: List[Tuple[str, str]]) -> str:
    if not pairs:
        return ""
    body = ",".join(f'{_LABEL_SANITIZE.sub("_", k)}="{_escape(v)}"'
                    for k, v in pairs)
    return "{" + body + "}"


def _fmt(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(registry) -> str:
    """The registry's current state as Prometheus exposition text."""
    lines: List[str] = []
    for name, type_name, children in registry.families():
        exp_name = _metric_name(name)
        lines.append(f"# TYPE {exp_name} {type_name}")
        for labels_key, metric in children:
            pairs = list(labels_key)
            if type_name == "histogram":
                cumulative = metric.cumulative()
                for bound, acc in zip(metric.bounds, cumulative):
                    lines.append(
                        f"{exp_name}_bucket"
                        f"{_render_labels(pairs + [('le', _fmt(bound))])}"
                        f" {acc}")
                lines.append(
                    f"{exp_name}_bucket"
                    f"{_render_labels(pairs + [('le', '+Inf')])}"
                    f" {cumulative[-1]}")
                lines.append(f"{exp_name}_sum{_render_labels(pairs)} "
                             f"{_fmt(metric.sum)}")
                lines.append(f"{exp_name}_count{_render_labels(pairs)} "
                             f"{metric.count}")
            else:
                lines.append(f"{exp_name}{_render_labels(pairs)} "
                             f"{_fmt(metric.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_snapshot(registry, path: str) -> None:
    with open(path, "w") as fh:
        fh.write(render_prometheus(registry))


# -- reader ------------------------------------------------------------------

_SAMPLE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)\s*$")
_LABEL_PAIR = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _unescape(value: str) -> str:
    return value.replace("\\n", "\n").replace("\\\"", "\"") \
                .replace("\\\\", "\\")


def _parse_value(text: str) -> float:
    if text == "+Inf":
        return math.inf
    if text == "-Inf":
        return -math.inf
    return float(text)        # float("NaN") handles NaN


def parse_exposition(text: str) -> Dict[str, dict]:
    """Decode exposition text into ``{name: {"type": ..., "samples":
    [(series_name, labels_dict, value), ...]}}``.

    Histogram child series (``_bucket``/``_sum``/``_count``) attach to
    their family name.  Raises ValueError on a malformed line, which is
    what makes this usable as a CI "snapshot parses" assertion.
    """
    families: Dict[str, dict] = {}
    types: Dict[str, str] = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
                families.setdefault(
                    parts[2], {"type": parts[3], "samples": []})
            continue
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"malformed exposition line {lineno}: {raw!r}")
        series = m.group("name")
        labels = {k: _unescape(v) for k, v in
                  _LABEL_PAIR.findall(m.group("labels") or "")}
        value = _parse_value(m.group("value"))
        fam_name = series
        for suffix in ("_bucket", "_sum", "_count"):
            base = series[:-len(suffix)] if series.endswith(suffix) else None
            if base and types.get(base) == "histogram":
                fam_name = base
                break
        fam = families.setdefault(
            fam_name, {"type": types.get(fam_name, "untyped"),
                       "samples": []})
        fam["samples"].append((series, labels, value))
    return families
