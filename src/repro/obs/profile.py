"""Profiling hooks (repro.obs): compiled-cost sampling, device memory,
and the ``--profile-dir`` trace window.

These reuse the same XLA surfaces the dryrun CLI reads (``lower() →
compile() → cost_analysis()`` and ``memory_stats()``), but packaged for
a live run: the Recorder samples FLOPs/bytes once per compiled step
function and device memory per log interval, so the numbers land next to
loss/latency in the same JSONL stream instead of in a separate dryrun
report.  Everything degrades to empty dicts on backends that don't
implement the introspection APIs — profiling must never fail a run.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional


def compiled_cost(jitted_fn, *args) -> Dict[str, float]:
    """FLOPs / bytes-accessed estimates for one compiled call.

    Lowers and compiles ``jitted_fn(*args)`` (AOT — a one-off cost, so
    call this once per distinct step function, not per step) and reads
    XLA's ``cost_analysis()``.  Returns ``{}`` when the backend doesn't
    report costs.
    """
    try:
        compiled = jitted_fn.lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        # jax<=0.4 returns a one-element list of dicts.
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
    except Exception:
        return {}
    out = {}
    for key, name in (("flops", "flops"), ("bytes accessed", "bytes")):
        v = ca.get(key)
        if v is not None:
            out[name] = float(v)
    return out


def device_memory_stats() -> Dict[str, float]:
    """Live/peak device memory in bytes for device 0, or ``{}`` (the CPU
    backend typically has no allocator stats)."""
    try:
        import jax
        dev = jax.devices()[0]
        stats = dev.memory_stats()
    except Exception:
        return {}
    if not stats:
        return {}
    out = {}
    for key, name in (("bytes_in_use", "bytes_in_use"),
                      ("peak_bytes_in_use", "peak_bytes_in_use")):
        v = stats.get(key)
        if v is not None:
            out[name] = float(v)
    return out


@contextlib.contextmanager
def profile_trace(profile_dir: Optional[str]):
    """A ``jax.profiler.trace`` window over the wrapped block.

    No-op when ``profile_dir`` is falsy (the default path: launch CLIs
    wrap their whole run in this unconditionally).  Spans opened inside
    the window appear as TraceAnnotation regions in the captured trace
    (obs/trace.py).  Failure to start the profiler — unsupported backend,
    unwritable dir — degrades to running unprofiled rather than raising.
    """
    if not profile_dir:
        yield
        return
    try:
        import jax
        jax.profiler.start_trace(profile_dir)
        started = True
    except Exception:
        started = False
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass


def sample_into(recorder, prefix: str = "device") -> None:
    """Drop current device-memory stats into ``recorder`` gauges
    (``device_bytes_in_use``, ``device_peak_bytes_in_use``).  Cheap no-op
    when metrics are off."""
    if not getattr(recorder, "metrics_enabled", False):
        return
    for name, v in device_memory_stats().items():
        recorder.gauge(f"{prefix}_{name}", v)
