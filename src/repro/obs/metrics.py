"""Event bus + metrics registry (repro.obs, DESIGN.md §12).

Two objects:

* :class:`MetricsRegistry` — in-process counters, gauges, and fixed-bucket
  histograms, keyed by ``(name, labels)``.  Pure stdlib, no jax imports;
  exported as a Prometheus-style text snapshot by ``obs/export.py``.

* :class:`Recorder` — the bus every instrumented path threads: ``emit()``
  (alias ``log()``, signature-compatible with the legacy
  ``TelemetryWriter.log``) fans one record out to the JSONL sinks AND
  mirrors its scalar fields into registry gauges; ``count()`` /
  ``gauge()`` / ``observe()`` update metrics directly; ``span()`` returns
  a timed context manager (``obs/trace.py``) that lands wall-times in the
  ``span_ms`` histogram.  A disabled Recorder (no sinks, no registry) costs
  one attribute check per call and allocates nothing — hot loops call it
  unconditionally, exactly like the old no-path TelemetryWriter.

The legacy ``defense/telemetry.TelemetryWriter`` survives unchanged as the
JSONL *sink backend*: the Recorder writes through it, so the on-disk format
(one ``{"t", "kind", "step", ...}`` record per line) and every existing
``read_jsonl`` consumer keep working.
"""
from __future__ import annotations

import dataclasses
import numbers
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.schema import check_kind

# Default wall-time buckets (milliseconds): sub-ms kernel calls up through
# multi-second compile-included steps, roughly 3x apart.
DEFAULT_MS_BUCKETS = (0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0, 300.0,
                      1000.0, 3000.0, 10000.0)

LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, object]) -> LabelsKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotone event count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        self.value += n


class Gauge:
    """Last-written point-in-time value."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram (Prometheus semantics: a bucket's upper bound
    ``le`` is inclusive; an implicit +Inf bucket catches the overflow)."""
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = DEFAULT_MS_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing and non-empty, got {bounds}")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)     # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # bisect_left: v == bounds[i] lands IN bucket i (le inclusive).
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> List[int]:
        """Per-``le`` cumulative counts, +Inf last (the exposition view)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation; +Inf bucket reports the last finite
        bound).  Good enough for p50/p99 dashboards, not for SLO math."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        for i, acc in enumerate(self.cumulative()):
            if acc >= rank:
                return self.bounds[i] if i < len(self.bounds) \
                    else self.bounds[-1]
        return self.bounds[-1]


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metric families, each holding one child per label set."""

    def __init__(self):
        # name -> (type_name, {labels_key: metric}, extra ctor args)
        self._families: Dict[str, tuple] = {}

    def _child(self, type_name: str, name: str, labels: Dict[str, object],
               ctor_args: tuple = ()):
        fam = self._families.get(name)
        if fam is None:
            fam = (type_name, {}, ctor_args)
            self._families[name] = fam
        elif fam[0] != type_name:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam[0]}, not {type_name}")
        key = _labels_key(labels)
        child = fam[1].get(key)
        if child is None:
            child = _KINDS[type_name](*fam[2])
            fam[1][key] = child
        return child

    # The metric-name parameter is positional-only so "name" stays legal
    # as a *label* key — span paths land in a "name" label.

    def counter(self, name: str, /, **labels) -> Counter:
        return self._child("counter", name, labels)

    def gauge(self, name: str, /, **labels) -> Gauge:
        return self._child("gauge", name, labels)

    def histogram(self, name: str, /,
                  buckets: Sequence[float] = DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        return self._child("histogram", name, labels, (tuple(buckets),))

    def families(self):
        """Sorted ``(name, type_name, [(labels_key, metric), ...])`` rows
        — the exposition iteration order, deterministic by construction."""
        for name in sorted(self._families):
            type_name, children, _ = self._families[name]
            yield name, type_name, sorted(children.items())

    def get(self, name: str, /, **labels):
        """The existing child metric, or None (never creates)."""
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam[1].get(_labels_key(labels))


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability switches a launch CLI maps its flags onto.

    ``enabled`` turns the metrics registry on; ``trace`` additionally arms
    span timing (host wall-clock with ``block_until_ready`` at span close —
    see obs/trace.py for the async-dispatch contract); ``metrics_path`` is
    where the Prometheus-style exposition snapshot lands when the Recorder
    closes; ``profile_dir`` captures a ``jax.profiler.trace`` window around
    the run (obs/profile.py); ``profile_cost`` samples per-step FLOPs/bytes
    from the compiled step via ``cost_analysis()`` (one extra lowering).
    """
    enabled: bool = True
    trace: bool = True
    metrics_path: Optional[str] = None
    profile_dir: Optional[str] = None
    profile_cost: bool = True


def _scalar(v) -> Optional[float]:
    """Float view of a plain/0-d numeric value, else None (cheap checks
    first: the disabled path must not import numpy per field)."""
    if isinstance(v, bool):
        return None
    if isinstance(v, numbers.Number):
        return float(v)
    shape = getattr(v, "shape", None)
    if shape == ():
        try:
            return float(v)
        except (TypeError, ValueError):
            return None
    return None


class Recorder:
    """The observability bus: JSONL sinks + metrics registry + tracer.

    ``sinks`` are TelemetryWriter-shaped objects (anything with
    ``log(kind, step, **fields)``); ``owned`` sinks are closed with the
    Recorder.  ``registry=None`` disables metrics, ``trace=False`` disables
    span timing — with both off and no sinks, every method is a cheap
    no-op, which is the mode hot loops run in by default.
    """

    def __init__(self, sinks: Sequence = (), registry:
                 Optional[MetricsRegistry] = None, trace: bool = False,
                 metrics_path: Optional[str] = None,
                 owned: Sequence = ()):
        self._sinks = list(sinks)
        self._owned = list(owned)
        self.registry = registry
        self.trace_enabled = bool(trace) and registry is not None
        self.metrics_path = metrics_path
        self._closed = False

    # -- construction ------------------------------------------------------

    @classmethod
    def disabled(cls) -> "Recorder":
        return cls()

    @property
    def enabled(self) -> bool:
        """Is anything listening (a sink or the registry)?"""
        return bool(self._sinks) or self.registry is not None

    @property
    def metrics_enabled(self) -> bool:
        return self.registry is not None

    # -- the event bus -----------------------------------------------------

    def _write(self, kind: str, step: int, **fields) -> None:
        """Sink-only write (no gauge mirroring) — the close-time registry
        dump and span records use this to avoid re-entering the registry."""
        check_kind(kind)
        for sink in self._sinks:
            sink.log(kind, step, **fields)

    def emit(self, kind: str, step: int, **fields) -> None:
        """One record onto the bus: validated kind, fanned out to every
        JSONL sink (legacy on-disk format), scalar fields mirrored into
        ``<kind>_<field>`` gauges when metrics are on."""
        if not (self._sinks or self.registry is not None):
            return
        self._write(kind, step, **fields)
        reg = self.registry
        if reg is not None:
            for k, v in fields.items():
                s = _scalar(v)
                if s is not None:
                    reg.gauge(f"{kind}_{k}").set(s)

    # Signature-compatible with TelemetryWriter.log, so a Recorder drops
    # into every call site that used to take the raw writer.
    log = emit

    # -- direct metric updates --------------------------------------------

    def count(self, name: str, n: float = 1.0, **labels) -> None:
        if self.registry is not None:
            self.registry.counter(name, **labels).inc(n)

    def gauge(self, name: str, value: float, **labels) -> None:
        if self.registry is not None:
            self.registry.gauge(name, **labels).set(value)

    def observe(self, name: str, value: float,
                buckets: Sequence[float] = DEFAULT_MS_BUCKETS,
                **labels) -> None:
        if self.registry is not None:
            self.registry.histogram(name, buckets, **labels).observe(value)

    def span(self, name: str, step_num: Optional[int] = None, **labels):
        """A timed span context manager, or the shared zero-cost no-op
        when tracing is off (``rec.span(...) is rec.span(...)`` then —
        nothing is allocated per call)."""
        from repro.obs.trace import NULL_SPAN, Span
        if not self.trace_enabled:
            return NULL_SPAN
        return Span(self, name, labels, step_num=step_num)

    # trace.Span calls back here when a span closes.
    def _span_done(self, path: str, ms: float, labels: Dict[str, object],
                   step_num: Optional[int]) -> None:
        if self.registry is not None:
            self.registry.histogram(
                "span_ms", DEFAULT_MS_BUCKETS,
                name=path, **labels).observe(ms)
        if self._sinks:
            self._write("span", step_num if step_num is not None else -1,
                        name=path, ms=ms, labels=dict(labels))

    # -- lifecycle ---------------------------------------------------------

    def snapshot(self) -> str:
        """The Prometheus-style exposition of the current registry state."""
        from repro.obs.export import render_prometheus
        if self.registry is None:
            return ""
        return render_prometheus(self.registry)

    def close(self) -> None:
        """Flush: dump the registry as ``metric`` records onto the JSONL
        sinks, write the exposition snapshot, close owned sinks."""
        if self._closed:
            return
        self._closed = True
        if self.registry is not None and self._sinks:
            for name, type_name, children in list(self.registry.families()):
                for labels_key, m in children:
                    value = (m.sum if type_name == "histogram" else m.value)
                    self._write("metric", -1, name=name, type=type_name,
                                value=float(value),
                                labels=dict(labels_key))
        if self.metrics_path and self.registry is not None:
            from repro.obs.export import write_snapshot
            write_snapshot(self.registry, self.metrics_path)
        for sink in self._owned:
            sink.close()
        self._owned = []

    def __enter__(self) -> "Recorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


#: Shared disabled recorder — the "None telemetry" of the bus world.
DISABLED = Recorder()


def as_recorder(obj) -> Recorder:
    """Adapt a telemetry argument to the bus: a Recorder passes through,
    a TelemetryWriter becomes a sink-only Recorder (not owned — the caller
    keeps closing it), None becomes the shared disabled Recorder."""
    if obj is None:
        return DISABLED
    if isinstance(obj, Recorder):
        return obj
    return Recorder(sinks=(obj,))


def make_recorder(telemetry_path: Optional[str] = None,
                  obs: Optional[ObsConfig] = None) -> Recorder:
    """The Recorder for one run: a JSONL sink when ``telemetry_path`` is
    set (owned — closed with the Recorder), a metrics registry + tracer
    when ``obs.enabled``.  Both off returns a disabled (but fresh,
    independently closeable) Recorder."""
    from repro.defense.telemetry import TelemetryWriter
    sinks, owned = [], []
    if telemetry_path:
        writer = TelemetryWriter(telemetry_path)
        sinks.append(writer)
        owned.append(writer)
    registry = MetricsRegistry() if (obs is not None and obs.enabled) \
        else None
    return Recorder(sinks=sinks, registry=registry,
                    trace=obs.trace if obs is not None else False,
                    metrics_path=obs.metrics_path if obs is not None
                    else None,
                    owned=owned)
