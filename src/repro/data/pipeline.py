"""Data pipeline: deterministic synthetic streams (offline container).

``TokenStream`` — an LM pretraining stand-in with *learnable structure*: a
fixed random bigram transition table generates token sequences, so the loss
has real signal (models reduce it well below uniform entropy).

``ClassificationData`` — the paper's MNIST/CIFAR stand-in: a Gaussian-mixture
multiclass problem (10 classes, configurable dim), the substrate for the
Byzantine-resilience experiments (benchmarks/fig2* etc.).

Both are pure-PRNG: every batch is a deterministic function of (seed, step),
which makes multi-host loading trivial (each host computes its shard) and
runs identically in tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_modes: int = 64               # bigram table rank (structure strength)

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        v = min(self.vocab_size, 4096)  # active vocab (keeps table small)
        logits = (2.5 * jax.random.normal(k1, (self.num_modes, v)))
        self._table = jax.nn.softmax(logits)           # (modes, v)
        self._mode_of = jax.random.randint(k2, (v,), 0, self.num_modes)
        self._active = v

    def batch(self, step: int) -> dict:
        """Returns {'tokens': (B,S), 'labels': (B,S)} — labels are the
        next-token targets (sequence shifted by one)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, S = self.global_batch, self.seq_len

        def gen_seq(k):
            k0, kscan = jax.random.split(k)
            first = jax.random.randint(k0, (), 0, self._active)

            def step_fn(tok, kk):
                nxt = jax.random.categorical(kk, jnp.log(
                    self._table[self._mode_of[tok]] + 1e-9))
                return nxt, nxt

            _, rest = jax.lax.scan(step_fn, first,
                                   jax.random.split(kscan, S))
            return jnp.concatenate([first[None], rest])

        toks = jax.vmap(gen_seq)(jax.random.split(key, B))   # (B, S+1)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


@dataclasses.dataclass
class ClassificationData:
    """Gaussian-mixture classification (paper experiment substrate)."""
    num_classes: int = 10
    dim: int = 784                    # MNIST-like
    noise: float = 1.0
    seed: int = 0

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        self.means = 2.0 * jax.random.normal(key, (self.num_classes, self.dim))

    def batch(self, step: int, batch_size: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (batch_size,), 0, self.num_classes)
        x = self.means[y] + self.noise * jax.random.normal(
            k2, (batch_size, self.dim))
        return {"x": x, "y": y}

    def test_set(self, n: int = 2048) -> dict:
        return self.batch(10_000_019, n)


def make_worker_batches(batch: dict, m: int) -> dict:
    """Reshape a global batch to (m, B/m, ...) worker groups (the paper's m
    workers — axis 0 is sharded over the mesh worker axes)."""
    def split(x):
        B = x.shape[0]
        assert B % m == 0, f"global batch {B} not divisible by m={m}"
        return x.reshape(m, B // m, *x.shape[1:])

    return jax.tree.map(split, batch)
