from repro.data.pipeline import (  # noqa: F401
    TokenStream, ClassificationData, make_worker_batches,
)
