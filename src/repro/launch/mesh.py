"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 (data, model) single pod, or 2×16×16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry-run) "
            "or on real hardware")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model),
                ("data", "model"))
