"""HLO text analyzer: loop-aware cost extraction from compiled dry-runs.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count (verified empirically), which silently undercounts scanned layer stacks
by ~num_layers×.  This module re-derives executed costs from the HLO text:

  * splits the module into computations,
  * builds the call graph (while bodies, fusions, calls, conditionals),
  * recovers static trip counts from each while's condition computation
    (induction variable compared against a constant),
  * propagates multipliers down the call graph, and
  * accumulates per-computation costs:
      - dot FLOPs (2 · prod(out) · contracted size) — the MXU term,
      - collective bytes by kind (all-gather / all-reduce / reduce-scatter /
        all-to-all / collective-permute) — the ICI term,
      - materialized buffer-write bytes — the HBM-traffic proxy (each op's
        output counts once; reads ≈ writes within 2× for fused pipelines).

All numbers are per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "u4": 1, "s4": 1}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?)\s+([\w\-]+)\((.*)$")

# ops whose "output" is a view/alias, not real HBM traffic
_NO_TRAFFIC = {"get-tuple-element", "tuple", "bitcast", "parameter",
               "constant", "after-all", "custom-call"}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.dot_flops = 0
        self.write_bytes = 0
        self.collective_bytes = {k: 0 for k in COLLECTIVE_KINDS}
        self.collective_counts = {k: 0 for k in COLLECTIVE_KINDS}
        # (callee, kind): kind in {while, call, fusion, cond}
        self.calls: List[Tuple[str, str]] = []
        # (body, cond, known_trip_count or None)
        self.while_pairs: List[Tuple[str, str, Optional[int]]] = []
        self.shapes: Dict[str, str] = {}               # op name -> shape text
        self.constants: List[int] = []


def _parse_dot_flops(shape_text: str, args_rest: str,
                     shapes: Dict[str, str]) -> int:
    out_elems, _ = _shape_elems_bytes(shape_text)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", args_rest)
    if not m:
        return 0
    cdims = [int(x) for x in m.group(1).split(",") if x]
    # lhs shape: post-scheduling HLO types every operand inline
    # ("dot(f32[64,64]{1,0} %lhs, ...)") — the first shape token of the
    # operand list IS the lhs shape.  Fall back to a named-op lookup for
    # untyped operand syntax ("dot(%lhs, %rhs)").  A bare name extraction
    # must not split on commas (shapes contain them: "f32[64,64]").
    dims_m = _SHAPE_TOKEN.search(args_rest)
    if dims_m is None or dims_m.start() >= args_rest.find(
            "lhs_contracting_dims"):
        ops = re.match(r"\s*%?([\w\.\-]+)", args_rest)
        lhs_name = ops.group(1) if ops else ""
        dims_m = _SHAPE_TOKEN.search(shapes.get(lhs_name, ""))
    if not dims_m:
        return 0
    dims = [int(x) for x in dims_m.group(2).split(",") if x]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2 * out_elems * k


def parse_hlo(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and ") -> " in stripped and "=" not in \
                stripped.split(") -> ")[0].split("(")[0]:
            m = _COMP_NAME.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        line = re.sub(r"/\*.*?\*/", "", line)          # strip /*index=N*/
        if " while(" in line:
            nm = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=", line)
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm = re.search(r"condition=%?([\w\.\-]+)", line)
            tm = re.search(r'known_trip_count.*?"n":"(\d+)"', line)
            if nm and bm and cm:
                cur.while_pairs.append(
                    (bm.group(1), cm.group(1),
                     int(tm.group(1)) if tm else None))
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, shape_text, opcode, rest = om.groups()
        cur.shapes[name] = shape_text
        _, out_bytes = _shape_elems_bytes(shape_text)
        if opcode == "dot":
            cur.dot_flops += _parse_dot_flops(shape_text, rest, cur.shapes)
            cur.write_bytes += out_bytes
        elif opcode in COLLECTIVE_KINDS or any(
                opcode == k + s for k in COLLECTIVE_KINDS
                for s in ("-start", "-done")):
            base = opcode.replace("-start", "").replace("-done", "")
            if opcode.endswith("-done"):
                continue                       # counted at -start
            cur.collective_bytes[base] += out_bytes
            cur.collective_counts[base] += 1
            cur.write_bytes += out_bytes
        elif opcode == "constant":
            cm = re.match(r"(\d+)\)", rest)
            if cm and shape_text.strip() in ("s32[]", "u32[]", "s64[]"):
                cur.constants.append(int(cm.group(1)))
            cur.write_bytes += 0
        elif opcode in ("fusion", "call"):
            fm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", rest)
            if fm:
                cur.calls.append((fm.group(1), opcode))
            cur.write_bytes += out_bytes
        elif opcode == "conditional":
            for fm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w\.\-]+))",
                                  rest):
                blob = fm.group(1) or fm.group(2) or ""
                for nm in re.findall(r"%?([\w\.\-]+)", blob):
                    cur.calls.append((nm, "cond"))
            cur.write_bytes += out_bytes
        elif opcode in _NO_TRAFFIC:
            pass
        else:
            cur.write_bytes += out_bytes
    comps["__entry__"] = comps.get(entry) or next(iter(comps.values()))
    return comps


def _trip_count(cond: Computation) -> int:
    """Static trip count heuristic: largest integer constant in the loop
    condition computation (the bound the induction variable is compared to)."""
    return max(cond.constants) if cond.constants else 1


def analyze_hlo(hlo: str) -> dict:
    comps = parse_hlo(hlo)
    entry = comps["__entry__"]
    totals = {"dot_flops": 0.0, "write_bytes": 0.0,
              "collective_bytes": {k: 0.0 for k in COLLECTIVE_KINDS},
              "collective_counts": {k: 0.0 for k in COLLECTIVE_KINDS},
              "loops": []}

    seen_stack = []

    def visit(comp: Computation, mult: float, in_fusion: bool):
        if comp.name in seen_stack:              # defensive: no recursion
            return
        seen_stack.append(comp.name)
        totals["dot_flops"] += mult * comp.dot_flops
        if not in_fusion:
            # fusion-internal op outputs live in registers/VMEM, not HBM —
            # only the fusion's own output (counted at the call site) is
            # real traffic.
            totals["write_bytes"] += mult * comp.write_bytes
        for k in COLLECTIVE_KINDS:
            totals["collective_bytes"][k] += mult * comp.collective_bytes[k]
            totals["collective_counts"][k] += mult * comp.collective_counts[k]
        for callee, kind in comp.calls:
            if callee in comps:
                visit(comps[callee], mult, in_fusion or kind == "fusion")
        for body, cond, known in comp.while_pairs:
            n = known if known is not None else (
                _trip_count(comps[cond]) if cond in comps else 1)
            totals["loops"].append({"body": body, "trips": n,
                                    "at_mult": mult})
            if body in comps:
                visit(comps[body], mult * n, in_fusion)
            if cond in comps:
                visit(comps[cond], mult * n, in_fusion)
        seen_stack.pop()

    visit(entry, 1.0, False)
    totals["collective_total_bytes"] = sum(
        totals["collective_bytes"].values())
    return totals
