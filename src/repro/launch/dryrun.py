"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
against the production mesh and extract roofline inputs.

MUST set the fake-device flag before ANY jax import (jax locks the device
count on first init) — hence the first two lines.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--layout sharded] ...
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch, get_shape, list_archs
from repro.core.robust import RobustConfig
from repro.dist.sharding import cache_pspec, tree_pspecs, worker_axes_of
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

def _with_sharding(spec_tree, pspec_tree, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        spec_tree, pspec_tree)


def _active_params(cfg, params_shapes) -> tuple:
    """(total, active) param counts; active discounts un-routed experts."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        name = "/".join(str(getattr(p, "key", "")) for p in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.is_moe and "moe_w" in name and "shared" not in name:
            active += n * cfg.num_experts_per_tok / cfg.num_experts
        else:
            active += n
    return total, int(active)


def build_lowerable(arch: str, shape_name: str, mesh, *, layout: str,
                    rule: str, b: int, remat: str, mode: str = "vmap"):
    """Returns (fn, arg_specs) ready for jit(...).lower(*arg_specs).

    mode: "vmap" (default — worker groups parallel over the data axis) or
    "streaming" (sequential workers, FSDP params over data+model; the
    O(b)-memory beyond-paper mode for 1T-scale archs)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg, remat=remat)
    wa = worker_axes_of(mesh)
    m = 1
    for a in wa:
        m *= mesh.shape[a]

    from repro.dist.sharding import param_pspec_fsdp
    leaf_rule = param_pspec_fsdp if mode == "streaming" else None
    params_shapes = jax.eval_shape(
        # eval_shape is abstract: only shapes flow out, no value is drawn
        lambda: model.init(jax.random.PRNGKey(0)))  # repro: noqa[PRNG004]
    pspecs = (tree_pspecs(params_shapes, mesh, leaf_rule=leaf_rule)
              if leaf_rule else tree_pspecs(params_shapes, mesh))
    params_sds = _with_sharding(params_shapes, pspecs, mesh)

    if shape.kind == "train":
        robust = RobustConfig(rule=rule, b=b, q=b, layout=layout)
        opt_cfg = OptConfig(name="sgd", lr=0.01)
        if mode == "streaming":
            from repro.train.streaming import make_streaming_train_step
            step = make_streaming_train_step(
                model, robust_cfg=robust, opt_cfg=opt_cfg, num_workers=m)
        else:
            step = make_train_step(model, robust_cfg=robust, opt_cfg=opt_cfg,
                                   num_workers=m, mesh=mesh)
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(opt_cfg, p), params_shapes)
        opt_sds = _with_sharding(
            opt_shapes,
            tree_pspecs(opt_shapes, mesh, leaf_rule=leaf_rule)
            if leaf_rule else tree_pspecs(opt_shapes, mesh), mesh)
        bspecs = model.input_specs(shape)
        batch_sds = {}
        for k, s in bspecs.items():
            B = s.shape[0]
            assert B % m == 0, f"{arch}/{shape_name}: batch {B} % m={m}"
            stacked = jax.ShapeDtypeStruct((m, B // m) + s.shape[1:], s.dtype)
            # streaming: worker axis scanned, per-worker batch data-sharded
            bspec = P(None, "data") if mode == "streaming" else P(wa)
            batch_sds[k] = jax.ShapeDtypeStruct(
                stacked.shape, stacked.dtype,
                sharding=NamedSharding(mesh, bspec))
        key_sds = jax.ShapeDtypeStruct(
            (2,), jnp.uint32, sharding=NamedSharding(mesh, P()))
        fn = step
        args = (params_sds, opt_sds, batch_sds, key_sds)
    elif shape.kind == "prefill":
        def fwd(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        fn = jax.jit(fwd)
        bspecs = model.input_specs(shape)
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=NamedSharding(
                    mesh, P(wa) if s.shape[0] % m == 0 else P()))
            for k, s in bspecs.items()}
        args = (params_sds, batch_sds)
    else:                                              # decode
        B = shape.global_batch
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq_len))
        cspecs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: cache_pspec(path, leaf, mesh), cache_shapes)
        cache_sds = _with_sharding(cache_shapes, cspecs, mesh)
        tok_spec = P(wa) if B % m == 0 else P()
        tok_sds = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
        pos_sds = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P()))

        def decode(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)
        fn = jax.jit(decode, donate_argnums=(1,))
        args = (params_sds, cache_sds, tok_sds, pos_sds)

    meta = {"total_params": None, "active_params": None}
    meta["total_params"], meta["active_params"] = _active_params(
        cfg, params_shapes)
    return fn, args, meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool, layout: str,
            rule: str, b: int, remat: str, outdir: str,
            skip_existing: bool = False, mode: str = "vmap") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}__{layout}__{rule}__{remat}"
    if mode != "vmap":
        tag += f"__{mode}"
    path = os.path.join(outdir, tag + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    os.makedirs(outdir, exist_ok=True)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "layout": layout, "rule": rule, "remat": remat, "mode": mode,
           "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with jax.set_mesh(mesh):       # activates shard_hint constraints
            fn, args, meta = build_lowerable(arch, shape_name, mesh,
                                             layout=layout, rule=rule, b=b,
                                             remat=remat, mode=mode)
            rec.update(meta)
            lowered = fn.lower(*args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):      # jax<=0.4 returns [dict]
            ca = ca[0] if ca else {}
        rec["xla_flops_raw"] = float(ca.get("flops", -1.0))
        rec["xla_bytes_raw"] = float(ca.get("bytes accessed", -1.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                rec[attr] = getattr(ma, attr, None)
        # Loop-aware per-device costs (XLA's cost_analysis counts while
        # bodies once — see hlo_analysis docstring).
        hlo = compiled.as_text()
        an = analyze_hlo(hlo)
        rec["dot_flops"] = an["dot_flops"]
        rec["write_bytes"] = an["write_bytes"]
        rec["collectives"] = {
            "bytes": an["collective_bytes"],
            "counts": an["collective_counts"],
            "total_bytes": an["collective_total_bytes"],
        }
        rec["loops"] = an["loops"][:40]
        rec["num_devices"] = mesh.size
        rec["ok"] = True
    except Exception as e:                             # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {tag}: {status}  ({rec['total_s']:.1f}s)", flush=True)
    return rec


# long_500k skips: pure full-attention archs (DESIGN.md §4)
LONG_SKIP = {"granite-8b", "kimi-k2-1t-a32b", "internvl2-26b",
             "whisper-large-v3", "deepseek-v2-lite-16b"}


def pairs():
    for arch in list_archs():
        for shape in INPUT_SHAPES:
            if shape == "long_500k" and arch in LONG_SKIP:
                continue
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--layout", default="sharded",
                    choices=["replicated", "sharded"])
    from repro.core import registry
    ap.add_argument("--rule", default="phocas",
                    choices=registry.available_rules())
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--mode", default="vmap", choices=["vmap", "streaming"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    todo = list(pairs()) if args.all else [(args.arch, args.shape)]
    n_ok = 0
    for arch, shape in todo:
        rec = run_one(arch, shape, multi_pod=args.multi_pod,
                      layout=args.layout, rule=args.rule, b=args.b,
                      remat=args.remat, outdir=args.out,
                      skip_existing=args.skip_existing, mode=args.mode)
        n_ok += bool(rec.get("ok"))
    print(f"[dryrun] {n_ok}/{len(todo)} OK")
    if n_ok != len(todo):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
