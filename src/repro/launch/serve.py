"""Serving launcher: batched greedy generation with the KV-cache runtime.

  python -m repro.launch.serve --arch gemma2-2b-reduced --batch 4 \
      --prompt-len 8 --new-tokens 16 [--mesh 4x2]

--mesh data×model serves over the local device set with the ``repro.dist``
layout (requests sharded over the data axis, KV heads over the model axis).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="data×model, e.g. 4x2; empty = single device")
    ap.add_argument("--telemetry", default="",
                    help="JSONL path for serve telemetry (shared "
                         "repro.defense.telemetry format)")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(data=d, model=m)

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompts, args.new_tokens, mesh=mesh)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    if args.telemetry:
        from repro.defense.telemetry import TelemetryWriter
        with TelemetryWriter(args.telemetry) as tel:
            tel.log("serve", 0, arch=args.arch, batch=args.batch,
                    prompt_len=args.prompt_len,
                    new_tokens=args.new_tokens, wall_s=dt, tok_s=tok_s,
                    mesh=args.mesh or "none")
    print(out[:, args.prompt_len:])


if __name__ == "__main__":
    main()
