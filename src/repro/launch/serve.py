"""Serving launcher (DESIGN.md §11).

Two paths behind one CLI:

* dense (default): static-batch greedy ``generate`` with batched prefill —
  ``--mesh DxM`` serves over the local device set with the ``repro.dist``
  layout (requests sharded over the data axis, KV heads over the model
  axis);
* engine (``--engine``, or implied by ``--replicas > 1``): the
  continuous-batching paged ``ServeEngine`` — ``--replicas k`` decodes
  with k model replicas aggregated per step by ``--robust-rule`` (any
  registered rule), ``--corrupt n`` replaces n replicas with garbage
  parameters to demonstrate the defense, and ``--telemetry`` streams the
  per-replica suspicion scores / reputation / ejection mask alongside the
  engine's queue-depth records (shared ``repro.defense.telemetry`` JSONL).

  python -m repro.launch.serve --arch granite-8b-reduced --batch 4 \
      --prompt-len 8 --new-tokens 16
  python -m repro.launch.serve --arch granite-8b-reduced --engine \
      --replicas 3 --robust-rule phocas --corrupt 1 --max-batch 8 \
      --telemetry results/serve.jsonl
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import generate


def _obs_config(args):
    """ObsConfig from the --metrics/--profile-dir flags, or None."""
    if not (args.metrics or args.profile_dir):
        return None
    from repro.obs import ObsConfig
    return ObsConfig(enabled=True, trace=True,
                     metrics_path=args.metrics or None,
                     profile_dir=args.profile_dir or None)


def _run_dense(args, model, params, key):
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 model.cfg.vocab_size)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(data=d, model=m)
    from repro.obs.profile import profile_trace
    t0 = time.time()
    with profile_trace(args.profile_dir or None):
        out = generate(model, params, prompts, args.new_tokens, mesh=mesh)
        jax.block_until_ready(out)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    if args.telemetry or args.metrics:
        from repro.obs import make_recorder
        with make_recorder(args.telemetry or None, _obs_config(args)) as rec:
            rec.log("serve", 0, arch=args.arch, batch=args.batch,
                    prompt_len=args.prompt_len,
                    new_tokens=args.new_tokens, wall_s=dt, tok_s=tok_s,
                    mesh=args.mesh or "none")
            rec.gauge("serve_tokens_per_sec", tok_s)
        if args.metrics:
            print(f"[serve] wrote metrics snapshot {args.metrics}")
    print(out[:, args.prompt_len:])


def _run_engine(args, model, params, key):
    import numpy as np
    from repro.obs import make_recorder
    from repro.serve import (RobustDecoder, ServeEngine, corrupt_replica,
                             make_replicas)

    decoder = None
    if args.replicas > 1:
        params = make_replicas(params, args.replicas)
        for i in range(args.corrupt):
            params = corrupt_replica(params, args.replicas - 1 - i,
                                     jax.random.fold_in(key, 1000 + i))
        decoder = RobustDecoder(rule=args.robust_rule, k=args.replicas)
    elif args.corrupt:
        raise SystemExit("--corrupt needs --replicas > 1")

    max_seq_len = args.prompt_len + args.new_tokens
    rng = np.random.default_rng(args.seed)
    with make_recorder(args.telemetry or None, _obs_config(args)) as rec:
        engine = ServeEngine(model, params, max_slots=args.max_batch,
                             max_seq_len=max_seq_len, decoder=decoder,
                             telemetry=rec)
        for _ in range(args.batch):
            engine.submit(
                rng.integers(0, model.cfg.vocab_size,
                             (args.prompt_len,)).tolist(),
                args.new_tokens)
        t0 = time.time()
        from repro.obs.profile import profile_trace
        with profile_trace(args.profile_dir or None):
            done = engine.run()
        dt = time.time() - t0
        rec.gauge("serve_tokens_per_sec",
                  sum(len(r.generated) for r in done) / max(dt, 1e-9))
    if args.metrics:
        print(f"[serve] wrote metrics snapshot {args.metrics}")
    toks = sum(len(r.generated) for r in done)
    lat = sorted(r.latency_ms() for r in done)
    mode = (f"robust k={args.replicas} {args.robust_rule}"
            if decoder is not None else "single")
    print(f"[serve] {args.arch} engine ({mode}): {len(done)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s, "
          f"p50 latency {lat[len(lat) // 2]:.0f}ms, "
          f"{engine.steps_run} engine steps)")
    if decoder is not None:
        print(f"[serve] replica reputation: "
              f"{np.asarray(decoder.rep_state['reputation']).round(3)} "
              f"ejected: {decoder.ejected_replicas()}")
    for r in done[: min(4, len(done))]:
        print(f"  rid={r.rid} -> {r.generated}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="request count (dense: static batch)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="data×model, e.g. 4x2; empty = single device "
                         "(dense path only)")
    ap.add_argument("--engine", action="store_true",
                    help="use the continuous-batching paged ServeEngine")
    ap.add_argument("--replicas", type=int, default=1,
                    help="k model replicas per decode step (> 1 implies "
                         "--engine and robust aggregation)")
    ap.add_argument("--robust-rule", default="phocas",
                    help="aggregation rule for replicated decode (any "
                         "registered rule)")
    ap.add_argument("--corrupt", type=int, default=0,
                    help="corrupt this many replicas with garbage params")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="engine slot count (concurrent requests)")
    ap.add_argument("--telemetry", default="",
                    help="JSONL path for serve + robust-decode score "
                         "telemetry (shared repro.defense.telemetry "
                         "format)")
    ap.add_argument("--metrics", default="",
                    help="arm the obs layer: write a Prometheus-style "
                         "exposition snapshot to this path at run end "
                         "(implies span tracing; see repro.obs)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run into "
                         "this directory (view with TensorBoard)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if args.engine or args.replicas > 1:
        _run_engine(args, model, params, key)
    else:
        _run_dense(args, model, params, key)


if __name__ == "__main__":
    main()
