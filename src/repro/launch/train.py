"""Training launcher: run Byzantine-resilient training for any --arch on the
local device set (real hardware) or demo scale.

  python -m repro.launch.train --arch gemma2-2b-reduced --steps 100 \
      --rule phocas --b 2 --attack gaussian --q 2 [--mesh 4x2]

On a real TPU slice, --mesh data×model builds the mesh over jax.devices();
the same flags drive the production 16×16 / 2×16×16 meshes.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.core import AttackConfig, RobustConfig, registry
from repro.data import TokenStream
from repro.models import build_model
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--workers", type=int, default=20)
    ap.add_argument("--rule", default="phocas",
                    choices=registry.available_rules())
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument("--layout", default="sharded")
    ap.add_argument("--attack", default="none",
                    choices=("none",) + registry.available_attacks())
    ap.add_argument("--q", type=int, default=0)
    ap.add_argument("--multikrum-k", type=int, default=None,
                    help="Multi-Krum selection size (default m-q-2)")
    ap.add_argument("--geomedian-iters", type=int, default=8)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--mesh", default="",
                    help="data×model, e.g. 4x2; empty = single device")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"),
                    help="per-rule kernel dispatch (rules with kernels: "
                         f"{', '.join(registry.kernel_rules())})")
    ap.add_argument("--use-kernels", action="store_true",
                    help="deprecated alias for --backend pallas")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--defense", action="store_true",
                    help="enable the repro.defense loop: per-worker "
                         "suspicion scores, EMA reputation with "
                         "ejection/readmission, online q-hat estimation")
    ap.add_argument("--reputation-decay", type=float, default=0.9,
                    help="EMA decay of the worker reputation state")
    ap.add_argument("--telemetry", default="",
                    help="JSONL path for per-step defense telemetry")
    args = ap.parse_args()
    if args.defense and args.rule not in registry.score_rules():
        # the default score hook is uniform zeros — the defense loop would
        # silently never detect or eject anything
        ap.error(f"--defense requires a score-emitting rule "
                 f"(emits_scores=True); {args.rule!r} is not one of "
                 f"{registry.score_rules()}")
    if args.use_kernels:
        print("[train] --use-kernels is deprecated; use --backend pallas")
        args.backend = "pallas"

    cfg = get_arch(args.arch)
    model = build_model(cfg, remat=args.remat)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(data=d, model=m)
        if args.workers != d:
            print(f"[train] overriding --workers to mesh data size {d}")
            args.workers = d

    robust = RobustConfig(
        rule=args.rule, b=args.b, q=args.q or args.b, layout=args.layout,
        multikrum_k=args.multikrum_k, geomedian_iters=args.geomedian_iters,
        backend=args.backend,
        attack=AttackConfig(name=args.attack, num_byzantine=args.q))
    opt = OptConfig(name=args.optimizer, lr=args.lr)
    tcfg = TrainerConfig(num_workers=args.workers, steps=args.steps,
                         log_every=max(args.steps // 20, 1),
                         checkpoint_path=args.checkpoint or None,
                         checkpoint_every=100 if args.checkpoint else 0)
    defense = None
    if args.defense:
        from repro.defense import DefenseConfig
        defense = DefenseConfig(reputation_decay=args.reputation_decay,
                                telemetry_path=args.telemetry or None)
    ds = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                     global_batch=args.global_batch)
    trainer = Trainer(model, ds.batch, tcfg, robust, opt, mesh=mesh,
                      defense_cfg=defense)
    print(f"[train] {args.arch}: {sum(x.size for x in jax.tree.leaves(trainer.params)):,} params, "
          f"rule={args.rule} b={args.b} attack={args.attack} "
          f"mesh={args.mesh or 'none'} defense={'on' if defense else 'off'}")
    trainer.run()
    if defense is not None and trainer.history and \
            "q_hat" in trainer.history[-1]:
        last = trainer.history[-1]
        print(f"[train] defense: q_hat={last['q_hat']} "
              f"active={last['n_active']}/{args.workers}")
    print("[train] done")


if __name__ == "__main__":
    main()
