"""Training launcher: a thin ``ScenarioSpec`` builder over
``repro.experiment.run_experiment`` — flags in, spec out, one entry point
for every topology (no topology-specific branching lives here).

  python -m repro.launch.train --arch gemma2-2b-reduced --steps 100 \
      --rule phocas --b 2 --attack gaussian --q 2 [--mesh 4x2] \
      [--topology sync_ps|async_ps|streaming]

Scenarios are first-class files:

  # run a checked-in scenario (the CI smoke matrix does exactly this)
  python -m repro.launch.train --scenario examples/scenarios/sync_gaussian.json
  # write the spec the flags describe, without running it
  python -m repro.launch.train --arch ... --dump-scenario my_run.json
"""
from __future__ import annotations

import argparse

import jax

from repro.core import AttackConfig, RobustConfig, registry
from repro.experiment import (ScenarioSpec, DataSpec, ModelSpec, SpecError,
                              available_topologies, run_experiment)
from repro.optim import OptConfig


def _parse_topology_params(items) -> dict:
    out = {}
    for item in items or ():
        if "=" not in item:
            raise SpecError(f"--topology-param needs key=value, got {item!r}")
        k, v = item.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def build_spec(args) -> ScenarioSpec:
    """Map CLI flags onto a ScenarioSpec (the only thing this CLI builds)."""
    workers = args.workers
    if args.mesh:
        from repro.experiment.spec import parse_mesh
        d, _ = parse_mesh(args.mesh)
        if workers != d:
            print(f"[train] overriding --workers to mesh data size {d}")
            workers = d
    if args.global_batch % workers:
        raise SpecError(f"--global-batch {args.global_batch} not divisible "
                        f"by workers={workers}")
    defense = None
    if args.defense:
        from repro.defense import DefenseConfig
        defense = DefenseConfig(reputation_decay=args.reputation_decay,
                                adapt_b=args.adapt_b,
                                telemetry_path=args.telemetry or None)
    return ScenarioSpec(
        name=f"{args.arch}-{args.rule}-{args.attack}",
        topology=args.topology,
        topology_params=_parse_topology_params(args.topology_param),
        model=ModelSpec(kind="arch", arch=args.arch, remat=args.remat),
        data=DataSpec(kind="tokens", seq_len=args.seq_len,
                      batch_per_worker=args.global_batch // workers),
        robust=RobustConfig(
            rule=args.rule, b=args.b, q=args.q or args.b,
            layout=args.layout, multikrum_k=args.multikrum_k,
            geomedian_iters=args.geomedian_iters, backend=args.backend),
        attack=AttackConfig(name=args.attack, num_byzantine=args.q),
        defense=defense,
        opt=OptConfig(name=args.optimizer, lr=args.lr),
        num_workers=workers,
        steps=args.steps,
        seed=args.seed,
        mesh=args.mesh,
        checkpoint_path=args.checkpoint,
        checkpoint_every=100 if args.checkpoint else 0,
        telemetry_path=args.telemetry,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="",
                    help="run a ScenarioSpec JSON file (all other spec "
                         "flags are ignored)")
    ap.add_argument("--dump-scenario", default="",
                    help="write the spec the flags describe to this path "
                         "and exit without running")
    ap.add_argument("--arch", default="")
    ap.add_argument("--topology", default="sync_ps",
                    choices=available_topologies())
    ap.add_argument("--topology-param", action="append", metavar="K=V",
                    help="topology plugin parameter (repeatable), e.g. "
                         "staleness=4 for async_ps")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--workers", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rule", default="phocas",
                    choices=registry.available_rules())
    ap.add_argument("--b", type=int, default=2)
    ap.add_argument("--layout", default="sharded")
    ap.add_argument("--attack", default="none",
                    choices=("none",) + registry.available_attacks())
    ap.add_argument("--q", type=int, default=0)
    ap.add_argument("--multikrum-k", type=int, default=None,
                    help="Multi-Krum selection size (default m-q-2)")
    ap.add_argument("--geomedian-iters", type=int, default=8)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--mesh", default="",
                    help="data×model, e.g. 4x2; empty = single device")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"),
                    help="per-rule kernel dispatch (rules with kernels: "
                         f"{', '.join(registry.kernel_rules())})")
    ap.add_argument("--use-kernels", action="store_true",
                    help="deprecated alias for --backend pallas")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--defense", action="store_true",
                    help="enable the repro.defense loop: per-worker "
                         "suspicion scores, EMA reputation with "
                         "ejection/readmission, online q-hat estimation")
    ap.add_argument("--adapt-b", action="store_true",
                    help="with --defense: feed the online q-hat back into "
                         "the rule's b/q (re-jit on adaptation)")
    ap.add_argument("--reputation-decay", type=float, default=0.9,
                    help="EMA decay of the worker reputation state")
    ap.add_argument("--telemetry", default="",
                    help="JSONL path for per-step defense telemetry")
    ap.add_argument("--metrics", default="",
                    help="arm the obs layer: write a Prometheus-style "
                         "exposition snapshot to this path at run end "
                         "(implies span tracing; see repro.obs)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run into "
                         "this directory (view with TensorBoard)")
    args = ap.parse_args()
    if args.use_kernels:
        print("[train] --use-kernels is deprecated; use --backend pallas")
        args.backend = "pallas"

    try:
        if args.scenario:
            spec = ScenarioSpec.load(args.scenario).validate()
        else:
            if not args.arch:
                ap.error("--arch is required (or pass --scenario FILE)")
            spec = build_spec(args).validate()
        if args.dump_scenario:
            spec.save(args.dump_scenario)
            print(f"[train] wrote {args.dump_scenario} "
                  f"({spec.name}: topology={spec.topology})")
            return
    except SpecError as e:
        ap.error(str(e))

    obs = None
    if args.metrics or args.profile_dir:
        from repro.obs import ObsConfig
        obs = ObsConfig(enabled=True, trace=True,
                        metrics_path=args.metrics or None,
                        profile_dir=args.profile_dir or None)

    from repro.obs.profile import profile_trace
    with profile_trace(args.profile_dir or None):
        result = run_experiment(spec, verbose=True, obs=obs)
    if args.metrics:
        print(f"[train] wrote metrics snapshot {args.metrics}")
    if args.profile_dir:
        print(f"[train] wrote profiler trace under {args.profile_dir}")
    n = sum(x.size for x in jax.tree.leaves(result.params))
    print(f"[train] {spec.name}: {n:,} params, topology={spec.topology} "
          f"rule={spec.robust.rule} b={result.robust_cfg.b} "
          f"attack={spec.effective_attack().name} "
          f"mesh={spec.mesh or 'none'} "
          f"defense={'on' if spec.defense else 'off'}")
    if result.history and "q_hat" in result.history[-1]:
        last = result.history[-1]
        print(f"[train] defense: q_hat={last['q_hat']} "
              f"active={last.get('n_active', '?')}/{spec.num_workers}")
    print("[train] done")


if __name__ == "__main__":
    main()
