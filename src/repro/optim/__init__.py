from repro.optim.optimizers import OptConfig, init_opt_state, apply_updates  # noqa: F401
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
