"""Optimizers as pure pytree transforms (no optax dependency).

The paper trains with plain SGD; momentum/Adam/AdamW are beyond-paper
extensions that compose with the robust aggregation (the robust rule replaces
the gradient *estimate*, everything downstream is unchanged — Theorems 3-4
only require the Δ bound on the aggregate).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "sgd"                 # sgd | momentum | adam | adamw
    lr: Schedule = 0.1                # paper default for MNIST MLP
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0            # 0 = off

    def lr_at(self, step) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


def init_opt_state(cfg: OptConfig, params):
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.name == "momentum":
        state["mu"] = zeros()
    elif cfg.name in ("adam", "adamw"):
        state["mu"] = zeros()
        state["nu"] = zeros()
    elif cfg.name != "sgd":
        raise ValueError(f"unknown optimizer {cfg.name!r}")
    return state


def _clip(cfg: OptConfig, grads):
    if not cfg.grad_clip:
        return grads
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def apply_updates(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state)."""
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    grads = _clip(cfg, grads)
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)

    if cfg.name == "sgd":
        upd = f32(grads)
        new_state = {"step": step}
    elif cfg.name == "momentum":
        mu = jax.tree.map(lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        upd = mu
        new_state = {"step": step, "mu": mu}
    else:                                           # adam / adamw
        mu = jax.tree.map(
            lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: cfg.beta2 * v
            + (1 - cfg.beta2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - cfg.beta1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.beta2 ** step.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps), mu, nu)
        new_state = {"step": step, "mu": mu, "nu": nu}

    def upd_leaf(p, u):
        u = u * lr
        if cfg.name == "adamw" and cfg.weight_decay and p.ndim >= 2:
            u = u + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - u).astype(p.dtype)

    return jax.tree.map(upd_leaf, params, upd), new_state
