"""Byzantine-resilient synchronous-SGD train step.

The paper's PS loop, as one SPMD program (DESIGN.md §2):

  1. the global batch is reshaped to (m, B/m, ...) worker groups; axis 0 is
     sharded over the mesh worker axes (data [+pod]) — each group is one of
     the paper's m workers;
  2. per-worker gradients come from ``vmap(value_and_grad)`` over the group
     axis (NOT a psum — the per-worker estimates must survive to the
     aggregation stage);
  3. the robust aggregation runs under ``shard_map`` with explicit
     collectives (replicated all-gather = paper-faithful PS; sharded
     all_to_all = the paper's multi-server partitioning as a robust
     reduce-scatter);
  4. the aggregated gradient feeds a standard optimizer update.

Attack injection (simulation of the paper's §5 adversaries) happens inside
stage 3, on the worker-gradient matrix — exactly where a real transmission-
medium corruption would land.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.robust import RobustConfig, aggregate_stacked_tree, \
    robust_aggregate_dist
from repro.dist.sharding import model_axes_of, tree_pspecs, worker_axes_of
from repro.optim.optimizers import OptConfig, apply_updates


def make_train_step(model, *, robust_cfg: RobustConfig, opt_cfg: OptConfig,
                    num_workers: int, mesh: Optional[Mesh] = None,
                    donate: bool = True, defense_cfg=None):
    """Build the jitted train step.

    Args:
      model: a ``repro.models.Model``.
      num_workers: m — worker groups per step.  In distributed mode must
        equal the product of the mesh worker-axis sizes.
      mesh: if None, aggregation runs locally (tests / laptop scale).
      defense_cfg: a ``repro.defense.DefenseConfig`` to enable the online
        defense loop (suspicion scores -> reputation EMA -> gated
        aggregation -> q̂); None keeps the plain paper-faithful step.

    Without defense, returns ``step(params, opt_state, batch, key) ->
    (params, opt_state, metrics)`` where batch leaves are worker-stacked
    (m, B/m, ...).  With defense, the step additionally threads the
    reputation state: ``step(params, opt_state, batch, key, defense) ->
    (params, opt_state, defense, metrics)`` and the metrics gain
    ``suspicion`` / ``reputation`` / ``active`` / ``q_hat``.
    """
    m = num_workers
    if mesh is not None:
        wa = worker_axes_of(mesh)
        msize = 1
        for a in wa:
            msize *= mesh.shape[a]
        if msize != m:
            raise ValueError(f"num_workers={m} != mesh worker axes size {msize}")
        ma = model_axes_of(mesh)

    def worker_loss(params, sub_batch):
        return model.loss(params, sub_batch)

    def worker_grads(params, batch):
        from repro.models import moe
        with moe.no_data_grouping():   # worker tokens are already shard-local
            return jax.vmap(jax.value_and_grad(worker_loss),
                            in_axes=(None, 0))(params, batch)

    def aggregate(params, grads, key, active, with_scores, train_step):
        """Robust aggregation in either layout; scores come back replicated.
        ``train_step`` (the optimizer's step counter) reaches step-aware
        adaptive attacks through the engine."""
        if mesh is None:
            return aggregate_stacked_tree(grads, robust_cfg, key,
                                          active=active,
                                          with_scores=with_scores,
                                          step=train_step)
        pspecs = tree_pspecs(params, mesh)
        stacked_specs = jax.tree.map(
            lambda sp: P(wa, *sp), pspecs,
            is_leaf=lambda x: isinstance(x, P))

        out_specs = (pspecs, P()) if with_scores else pspecs
        if active is None:
            def agg_fn(g, k, ts):
                local = jax.tree.map(lambda x: x[0], g)
                return robust_aggregate_dist(local, robust_cfg,
                                             worker_axes=wa, model_axes=ma,
                                             key=k, with_scores=with_scores,
                                             step=ts)

            return jax.shard_map(agg_fn, mesh=mesh,
                                 in_specs=(stacked_specs, P(), P()),
                                 out_specs=out_specs,
                                 check_vma=False)(grads, key, train_step)

        def agg_gated(g, k, act, ts):
            local = jax.tree.map(lambda x: x[0], g)
            return robust_aggregate_dist(local, robust_cfg,
                                         worker_axes=wa, model_axes=ma,
                                         key=k, active=act,
                                         with_scores=with_scores, step=ts)

        return jax.shard_map(agg_gated, mesh=mesh,
                             in_specs=(stacked_specs, P(), P(), P()),
                             out_specs=out_specs,
                             check_vma=False)(grads, key, active, train_step)

    def step(params, opt_state, batch, key):
        losses, grads = worker_grads(params, batch)
        agg = aggregate(params, grads, key, None, False, opt_state["step"])
        params, opt_state = apply_updates(opt_cfg, params, agg, opt_state)
        metrics = {"loss": jnp.mean(losses),
                   "loss_per_worker": losses,
                   "grad_norm": _tree_norm(agg)}
        return params, opt_state, metrics

    def defense_step(params, opt_state, batch, key, defense):
        from repro.defense.detector import estimate_q
        from repro.defense.reputation import update_reputation
        losses, grads = worker_grads(params, batch)
        agg, scores = aggregate(params, grads, key, defense["active"], True,
                                opt_state["step"])
        defense = update_reputation(defense, scores, defense_cfg)
        params, opt_state = apply_updates(opt_cfg, params, agg, opt_state)
        metrics = {"loss": jnp.mean(losses),
                   "loss_per_worker": losses,
                   "grad_norm": _tree_norm(agg),
                   "suspicion": scores,
                   "reputation": defense["reputation"],
                   "active": defense["active"],
                   "q_hat": estimate_q(
                       scores, min_gap=defense_cfg.detector_min_gap)}
        return params, opt_state, defense, metrics

    donate_argnums = (0, 1) if donate else ()
    if defense_cfg is not None:
        return jax.jit(defense_step, donate_argnums=donate_argnums)
    return jax.jit(step, donate_argnums=donate_argnums)


def _tree_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def shard_params(params, mesh: Mesh):
    """Device-put params according to the TP rules (entry point for real
    multi-device runs)."""
    specs = tree_pspecs(params, mesh)
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        params, specs)
