"""Streaming (memory-bounded) robust aggregation — beyond-paper extension.

The paper's rules need all m worker gradients simultaneously: O(m·|θ|)
memory.  At trillion-parameter scale that cannot exist on any single mesh
(kimi-k2: m=16 × 2 TB).  This mode reformulates the coordinate-wise rules as
STREAMING statistics over a sequential scan of workers:

  Trmean_b  =  (Σ g_i − Σ bottom-b − Σ top-b) / (m − 2b)
     — maintain per-coordinate running sum + the b smallest and b largest
       values seen: O((2b+1)·|θ|) instead of O(m·|θ|).

  Phocas_b  =  (Σ g_i − Σ of the b values farthest from Trmean) / (m − b)
     — needs the trimmed mean first, so a SECOND scan recomputes each
       worker gradient (gradient rematerialization — same trick as
       activation remat: trade 2× compute for m/(2b+1)× memory) and
       maintains the top-b (distance, value) pairs.

Both are EXACT (not approximations) — verified against the batch rules in
tests/test_streaming.py.  Because workers are processed sequentially, the
mesh's data axis is free for FSDP parameter sharding instead of worker
parallelism: every device cooperates on one worker's gradient at a time.

Attack simulation supports the per-worker-computable adversaries
(gaussian / signflip / zero / bitflip / gambler).  Omniscient needs all
correct gradients at once and is vmap-mode-only.
"""
from __future__ import annotations

import zlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import registry
from repro.core.attacks import AttackConfig, _flip_bits_f32
from repro.core.robust import RobustConfig
from repro.optim.optimizers import OptConfig, apply_updates


# Attacks computable one worker at a time (the streaming scan never holds
# the full worker matrix, so collusion-style adversaries — omniscient,
# innerprod, slowburn — cannot be simulated here).  Spec validation
# (repro.experiment) reads this to reject unsupported cells at build time.
STREAMING_ATTACKS = ("none", "gaussian", "signflip", "zero", "bitflip",
                     "gambler")

# Rules make_streaming_train_step actually has a streaming formulation
# for.  Must stay in bijection with the registry's supports_streaming
# metadata — rule CONTRACT003 of ``repro.analysis`` checks both ways.
STREAMING_IMPL_RULES = ("mean", "trmean", "phocas")


def _path_salt(path) -> int:
    """Deterministic 31-bit fold-in salt from a leaf's tree path.

    Derived from the *path*, not the shape: ``hash(str(shape))`` is salted
    per-process (PYTHONHASHSEED), so two processes of one logical run would
    draw different attack noise, and same-shape leaves would collide on
    identical noise.  CRC32 of the key-path string is stable across
    processes and unique per leaf."""
    return zlib.crc32(jax.tree_util.keystr(path).encode("utf-8")) & 0x7FFFFFFF


def _worker_attack(cfg: AttackConfig, g, widx, key, center=None):
    """Apply a per-worker-computable attack to worker ``widx``'s gradient
    pytree (the streaming analogue of core.attacks on the (m,d) matrix)."""
    name = cfg.name.lower()
    if name in ("none", ""):
        return g
    q = cfg.num_byzantine

    if name == "gaussian":
        # Salt by leaf path AND worker index: without the widx fold every
        # Byzantine worker drew the SAME noise vector, making the q rows
        # collinear — a much weaker adversary than the matrix-mode attack,
        # which draws (q, d) independent noise (repro.analysis audit).
        def leaf(path, x):
            noise = cfg.gaussian_std * jax.random.normal(
                jax.random.fold_in(
                    jax.random.fold_in(key, _path_salt(path)), widx),
                x.shape, jnp.float32)
            return jnp.where(widx < q, noise.astype(x.dtype), x)
        return jax.tree_util.tree_map_with_path(leaf, g)
    if name == "signflip":
        return jax.tree.map(
            lambda x: jnp.where(widx < q, -10.0 * x, x), g)
    if name == "zero":
        return jax.tree.map(
            lambda x: jnp.where(widx < q, jnp.zeros_like(x), x), g)
    if name == "bitflip":
        # per-dimension random victim row == widx (Definition 4 placement)
        def leaf(i, x):
            kk = jax.random.fold_in(key, i)
            victim = jax.random.randint(kk, x.shape, 0, 20)  # row draw
            hit = victim == (widx % 20)
            flipped = _flip_bits_f32(x.astype(jnp.float32), cfg.bitflip_bits)
            return jnp.where(hit, flipped, x.astype(jnp.float32)).astype(x.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(g)
        return jax.tree_util.tree_unflatten(
            treedef, [leaf(i, x) for i, x in enumerate(leaves)])
    if name == "gambler":
        def leaf(i, x):
            kk = jax.random.fold_in(key, 7919 + i)
            hit = jax.random.bernoulli(kk, cfg.gambler_prob, x.shape)
            return jnp.where(hit, cfg.gambler_scale * x, x)
        leaves, treedef = jax.tree_util.tree_flatten(g)
        return jax.tree_util.tree_unflatten(
            treedef, [leaf(i, x) for i, x in enumerate(leaves)])
    raise ValueError(f"attack {cfg.name!r} not supported in streaming mode "
                     f"(supported: {STREAMING_ATTACKS}; omniscient/innerprod/"
                     "slowburn need all worker gradients at once)")


def _merge_bottom(bot, g):
    """bot: (b, *s) smallest-so-far; returns updated (b, *s)."""
    cat = jnp.concatenate([bot, g[None].astype(bot.dtype)], axis=0)
    return jnp.sort(cat, axis=0)[:-1]


def _merge_top(top, g):
    cat = jnp.concatenate([top, g[None].astype(top.dtype)], axis=0)
    return jnp.sort(cat, axis=0)[1:]


def _merge_top_by_dist(dtop, vtop, d, v):
    """Keep the b (distance, value) pairs with largest distance."""
    dc = jnp.concatenate([dtop, d[None].astype(dtop.dtype)], axis=0)
    vc = jnp.concatenate([vtop, v[None].astype(vtop.dtype)], axis=0)
    order = jnp.argsort(dc, axis=0)[1:]                  # drop smallest
    return (jnp.take_along_axis(dc, order, axis=0),
            jnp.take_along_axis(vc, order, axis=0))


def make_streaming_train_step(model, *, robust_cfg: RobustConfig,
                              opt_cfg: OptConfig, num_workers: int,
                              mesh: Optional[Mesh] = None,
                              stats_dtype=jnp.float32):
    """Streaming-mode train step: batch leaves (m, B/m, ...) are scanned
    sequentially over the worker axis; all devices (incl. the data axis,
    free for FSDP) cooperate on each worker's gradient."""
    m = num_workers
    b = robust_cfg.b
    rule = robust_cfg.rule
    if not registry.get_rule(rule).supports_streaming:
        raise ValueError(
            f"streaming mode supports {registry.streaming_rules()}, got "
            f"{rule!r} (rules opt in via supports_streaming=True)")
    if not 0 <= b <= (m + 1) // 2 - 1:
        raise ValueError(f"b={b} out of range for m={m}")

    def worker_grad(params, sub, widx, key):
        g = jax.grad(model.loss)(params, sub)
        g = jax.tree.map(lambda x: x.astype(stats_dtype), g)
        return _worker_attack(robust_cfg.attack, g, widx, key)

    def step(params, opt_state, batch, key):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, stats_dtype), params)
        big = jax.tree.map(
            lambda p: jnp.full((b,) + p.shape, jnp.inf, stats_dtype), params)

        def pass1(carry, xs):
            ssum, bot, top = carry
            widx, sub = xs
            g = worker_grad(params, sub, widx, key)
            ssum = jax.tree.map(lambda s, x: s + x, ssum, g)
            if b:
                bot = jax.tree.map(_merge_bottom, bot, g)
                top = jax.tree.map(_merge_top, top, g)
            loss = model.loss(params, sub)
            return (ssum, bot, top), loss

        widxs = jnp.arange(m)
        neg = jax.tree.map(lambda x: -x, big)
        (ssum, bot, top), losses = jax.lax.scan(
            pass1, (zeros, big, neg), (widxs, batch))

        if rule == "mean" or b == 0:
            agg = jax.tree.map(lambda s: s / m, ssum)
        else:
            center = jax.tree.map(
                lambda s, lo, hi: (s - lo.sum(0) - hi.sum(0)) / (m - 2 * b),
                ssum, bot, top)
            if rule == "trmean":
                agg = center
            else:                                   # phocas: second pass
                dz = jax.tree.map(
                    lambda p: jnp.full((b,) + p.shape, -jnp.inf,
                                       stats_dtype), params)
                vz = jax.tree.map(
                    lambda p: jnp.zeros((b,) + p.shape, stats_dtype), params)

                def pass2(carry, xs):
                    dtop, vtop = carry
                    widx, sub = xs
                    g = worker_grad(params, sub, widx, key)  # recompute
                    d = jax.tree.map(
                        lambda x, c: jnp.abs(x - c), g, center)
                    # O(1)-memory per-worker suspicion: total L1 distance
                    # mass from the robust center (the streaming analogue
                    # of the batch rules' selection-mask scores — exact
                    # masks would need a third scan).
                    mass = sum(jnp.sum(x) for x in jax.tree.leaves(d))
                    merged = jax.tree.map(_merge_top_by_dist, dtop, vtop,
                                          d, g)
                    dtop = jax.tree.map(lambda t: t[0], merged,
                                        is_leaf=lambda x: isinstance(x, tuple))
                    vtop = jax.tree.map(lambda t: t[1], merged,
                                        is_leaf=lambda x: isinstance(x, tuple))
                    return (dtop, vtop), mass

                (dtop, vtop), masses = jax.lax.scan(pass2, (dz, vz),
                                                    (widxs, batch))
                from repro.defense.scores import distance_ratio_scores
                suspicion = distance_ratio_scores(masses)
                agg = jax.tree.map(
                    lambda s, v: (s - v.sum(0)) / (m - b), ssum, vtop)

        agg = jax.tree.map(lambda a, p: a.astype(p.dtype), agg, params)
        params2, opt_state2 = apply_updates(opt_cfg, params, agg, opt_state)
        metrics = {"loss": jnp.mean(losses), "loss_per_worker": losses}
        if rule == "phocas" and b:
            metrics["suspicion"] = suspicion
        return params2, opt_state2, metrics

    return jax.jit(step)


def run_streaming_training(model, batch_fn: Callable[[int], dict],
                           robust_cfg: RobustConfig, opt_cfg: OptConfig,
                           *, num_workers: int, steps: int,
                           seed: int = 0,
                           eval_fn: Optional[Callable] = None,
                           telemetry_path: Optional[str] = None) -> list:
    """Deprecated driver shim: delegates to the ``streaming`` topology
    (``repro.experiment``), which owns the loop (same JSONL telemetry,
    kind="streaming").  New code should build a ``ScenarioSpec`` with
    ``topology="streaming"`` and call ``run_experiment``."""
    from repro.experiment.runner import plan_from_parts
    from repro.experiment.topology import make_topology
    plan = plan_from_parts(
        model=model, batch_fn=batch_fn, robust_cfg=robust_cfg,
        opt_cfg=opt_cfg, num_workers=num_workers, steps=steps, seed=seed,
        topology="streaming", eval_fn=eval_fn, record_every=10,
        telemetry_path=telemetry_path)
    return make_topology("streaming").run(plan).history
