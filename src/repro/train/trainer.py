"""Training loop driver: data -> worker batches -> robust step -> metrics,
with periodic checkpointing.  Used by the examples and the paper-repro
benchmarks (laptop scale); the same step function scales to the production
mesh via launch/train.py.

With a ``repro.defense.DefenseConfig`` the loop closes the detection loop:
per-step suspicion scores update the EMA reputation state (threaded through
the jitted step and checkpointed alongside params/opt), ejected workers are
gated out of the aggregation, and every step's defense metrics stream to
the structured JSONL telemetry sink."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.robust import RobustConfig
from repro.data.pipeline import make_worker_batches
from repro.optim.optimizers import OptConfig
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    num_workers: int = 20             # paper: m = 20
    steps: int = 500
    log_every: int = 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0


class Trainer:
    def __init__(self, model, batch_fn: Callable[[int], dict],
                 tcfg: TrainerConfig, robust_cfg: RobustConfig,
                 opt_cfg: OptConfig, mesh=None,
                 eval_fn: Optional[Callable] = None,
                 defense_cfg=None):
        self.model = model
        self.batch_fn = batch_fn
        self.tcfg = tcfg
        self.eval_fn = eval_fn
        self.defense_cfg = defense_cfg
        self.step_fn = make_train_step(
            model, robust_cfg=robust_cfg, opt_cfg=opt_cfg,
            num_workers=tcfg.num_workers, mesh=mesh, donate=False,
            defense_cfg=defense_cfg)
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = model.init(key)
        if mesh is not None:
            # place params (and hence opt state) on the repro.dist TP layout
            from repro.train.step import shard_params
            self.params = shard_params(self.params, mesh)
        from repro.optim.optimizers import init_opt_state
        self.opt_state = init_opt_state(opt_cfg, self.params)
        self.defense_state = None
        if defense_cfg is not None:
            from repro.defense.reputation import init_reputation
            self.defense_state = init_reputation(tcfg.num_workers)
        self.history: list = []

    def _checkpoint_tree(self) -> dict:
        tree = {"params": self.params, "opt": self.opt_state}
        if self.defense_state is not None:
            tree["defense"] = self.defense_state
        return tree

    def restore(self, path: str) -> int:
        """Restore params/opt (and reputation state, when defense is on)
        from a checkpoint written by :meth:`run`; returns the saved step."""
        from repro.checkpoint.io import load_checkpoint
        tree, step = load_checkpoint(path, self._checkpoint_tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        if self.defense_state is not None:
            self.defense_state = tree["defense"]
        return step

    def run(self, verbose: bool = True) -> list:
        from repro.defense.telemetry import TelemetryWriter
        key = jax.random.PRNGKey(self.tcfg.seed + 1)
        telemetry_path = (self.defense_cfg.telemetry_path
                          if self.defense_cfg is not None else None)
        t0 = time.time()
        with TelemetryWriter(telemetry_path) as tel:
            for step in range(self.tcfg.steps):
                batch = make_worker_batches(self.batch_fn(step),
                                            self.tcfg.num_workers)
                key, sk = jax.random.split(key)
                if self.defense_state is not None:
                    (self.params, self.opt_state, self.defense_state,
                     metrics) = self.step_fn(self.params, self.opt_state,
                                             batch, sk, self.defense_state)
                    tel.log("train", step,
                            loss=metrics["loss"],
                            grad_norm=metrics["grad_norm"],
                            suspicion=metrics["suspicion"],
                            reputation=metrics["reputation"],
                            active=metrics["active"],
                            q_hat=metrics["q_hat"])
                else:
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch, sk)
                if step % self.tcfg.log_every == 0 or \
                        step == self.tcfg.steps - 1:
                    rec = {"step": step, "loss": float(metrics["loss"]),
                           "grad_norm": float(metrics["grad_norm"]),
                           "wall": time.time() - t0}
                    if "q_hat" in metrics:
                        rec["q_hat"] = int(metrics["q_hat"])
                        rec["n_active"] = int(jnp.sum(metrics["active"]))
                    if self.eval_fn is not None:
                        rec["eval"] = float(self.eval_fn(self.params))
                    self.history.append(rec)
                    if verbose:
                        msg = (f"step {step:5d}  loss {rec['loss']:.4f}  "
                               f"gnorm {rec['grad_norm']:.3e}")
                        if "q_hat" in rec:
                            msg += (f"  qhat {rec['q_hat']}  "
                                    f"active {rec['n_active']}")
                        if "eval" in rec:
                            msg += f"  eval {rec['eval']:.4f}"
                        print(msg, flush=True)
                if (self.tcfg.checkpoint_path and self.tcfg.checkpoint_every
                        and step and step % self.tcfg.checkpoint_every == 0):
                    from repro.checkpoint.io import save_checkpoint
                    save_checkpoint(self.tcfg.checkpoint_path,
                                    self._checkpoint_tree(), step=step)
        return self.history
