"""Deprecated sync-PS driver shim.

``Trainer`` predates the declarative experiment API; the loop it used to
own (batching, telemetry, history records, checkpointing) now lives in the
``sync_ps`` topology plugin (``repro.experiment.topologies.SyncPS``), and
this class is a thin delegation kept so existing call sites and
checkpoints keep working.  New code should build a
``repro.experiment.ScenarioSpec`` and call ``run_experiment`` instead —
see DESIGN.md §9 for the migration map.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax

from repro.core.robust import RobustConfig
from repro.optim.optimizers import OptConfig


@dataclasses.dataclass
class TrainerConfig:
    num_workers: int = 20             # paper: m = 20
    steps: int = 500
    log_every: int = 50
    seed: int = 0
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0


class Trainer:
    """Deprecated: delegates to the ``sync_ps`` topology — one loop for
    shim and spec-built runs, so trajectories are identical step-for-step."""

    def __init__(self, model, batch_fn: Callable[[int], dict],
                 tcfg: TrainerConfig, robust_cfg: RobustConfig,
                 opt_cfg: OptConfig, mesh=None,
                 eval_fn: Optional[Callable] = None,
                 defense_cfg=None):
        self.model = model
        self.batch_fn = batch_fn
        self.tcfg = tcfg
        self.robust_cfg = robust_cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.eval_fn = eval_fn
        self.defense_cfg = defense_cfg
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = model.init(key)
        if mesh is not None:
            # place params (and hence opt state) on the repro.dist TP layout
            from repro.train.step import shard_params
            self.params = shard_params(self.params, mesh)
        from repro.optim.optimizers import init_opt_state
        self.opt_state = init_opt_state(opt_cfg, self.params)
        self.defense_state = None
        if defense_cfg is not None:
            from repro.defense.reputation import init_reputation
            self.defense_state = init_reputation(tcfg.num_workers)
        self.history: list = []

    def _checkpoint_tree(self) -> dict:
        tree = {"params": self.params, "opt": self.opt_state}
        if self.defense_state is not None:
            tree["defense"] = self.defense_state
        return tree

    def restore(self, path: str) -> int:
        """Restore params/opt (and reputation state, when defense is on)
        from a checkpoint written by :meth:`run`; returns the saved step."""
        from repro.checkpoint.io import load_checkpoint
        tree, step = load_checkpoint(path, self._checkpoint_tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        if self.defense_state is not None:
            self.defense_state = tree["defense"]
        return step

    def run(self, verbose: bool = True) -> list:
        from repro.experiment.runner import plan_from_parts
        from repro.experiment.topology import make_topology
        plan = plan_from_parts(
            model=self.model, batch_fn=self.batch_fn,
            robust_cfg=self.robust_cfg, opt_cfg=self.opt_cfg,
            num_workers=self.tcfg.num_workers, steps=self.tcfg.steps,
            seed=self.tcfg.seed, eval_fn=self.eval_fn,
            defense_cfg=self.defense_cfg, mesh=self.mesh,
            record_every=self.tcfg.log_every,
            checkpoint_path=self.tcfg.checkpoint_path,
            checkpoint_every=self.tcfg.checkpoint_every,
            telemetry_path=(self.defense_cfg.telemetry_path
                            if self.defense_cfg is not None else None),
            verbose=verbose)
        result = make_topology("sync_ps").run(
            plan, init_state=(self.params, self.opt_state,
                              self.defense_state))
        self.params = result.params
        self.opt_state = result.opt_state
        self.defense_state = result.defense_state
        self.robust_cfg = result.robust_cfg   # post-adapt_b effective config
        self.history = result.history
        return self.history
