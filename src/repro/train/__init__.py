from repro.train.step import make_train_step  # noqa: F401
from repro.train.streaming import (  # noqa: F401
    make_streaming_train_step, run_streaming_training,
)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
