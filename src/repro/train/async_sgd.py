"""Asynchronous Byzantine-resilient SGD — the paper's stated future work
("we will study the Byzantine resilience in other scenarios such as
asynchronous training") made concrete.

Model: a buffered-asynchronous parameter server (à la backup-worker /
buffered-async schemes).  Each worker computes gradients against a STALE
parameter copy (staleness ≤ tau steps — workers refresh their copy with
probability 1/tau per step, a geometric staleness model); the server keeps
the latest gradient from each worker in an m-slot buffer and applies a
dimensional-robust rule over the buffer every step.

Because Trmean/Phocas only need the per-coordinate value multiset, the
buffer IS the {tilde v_i} set of Definition 5 — staleness perturbs the
correct gradients (bounded-drift bias) while Byzantine slots stay arbitrary,
so the Δ-resilience argument carries over with V inflated by the staleness
drift.  The simulation (tests/test_async.py, benchmarks run) shows the
qualitative claim: async-Phocas converges under attacks that destroy
async-Mean.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.robust import RobustConfig, aggregate_stacked_tree
from repro.optim.optimizers import OptConfig, apply_updates, init_opt_state


@dataclasses.dataclass
class AsyncConfig:
    num_workers: int = 20
    staleness: int = 4                 # tau: expected staleness in steps
    update_clip: float = 10.0          # global-norm bound on the applied update
    seed: int = 0


def make_async_train_step(model, *, robust_cfg: RobustConfig,
                          opt_cfg: OptConfig, acfg: AsyncConfig,
                          defense_cfg=None):
    """Returns (init_state, step) for the buffered-async simulation.

    State carries the server params/opt plus each worker's stale parameter
    copy and the m-slot gradient buffer.  ``step(state, batch, key)`` runs
    one server iteration: every worker contributes the gradient of ITS stale
    copy on ITS batch shard; workers refresh their copy w.p. 1/tau.

    With ``defense_cfg`` the state additionally carries the
    ``repro.defense`` reputation dict: the buffer aggregation is
    reputation-gated and every step updates the EMA from the rule's
    suspicion scores.  Staleness makes honest workers *mildly* suspicious
    (their gradients drift from the fresh majority), which is exactly what
    the EMA + hysteresis smoothing is for — a stale-but-honest worker's
    reputation hovers well above the ejection threshold while a Byzantine
    slot's collapses.
    """
    m = acfg.num_workers

    def init_state(key):
        params = model.init(key)
        state = {
            "params": params,
            "opt": init_opt_state(opt_cfg, params),
            # every worker starts synchronized
            "worker_params": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (m,) + x.shape), params),
            "buffer": jax.tree.map(
                lambda x: jnp.zeros((m,) + x.shape, jnp.float32), params),
        }
        if defense_cfg is not None:
            from repro.defense.reputation import init_reputation
            state["defense"] = init_reputation(m)
        return state

    def worker_grad(wparams, sub_batch):
        return jax.grad(model.loss)(wparams, sub_batch)

    def step(state, batch, key):
        """batch leaves: (m, B/m, ...)."""
        k_refresh, k_attack = jax.random.split(key)
        grads = jax.vmap(worker_grad)(state["worker_params"], batch)
        grads = jax.tree.map(lambda x: x.astype(jnp.float32), grads)
        buffer = grads                              # every slot refreshed

        defense = None
        extra_metrics = {}
        if defense_cfg is not None:
            from repro.defense.detector import estimate_q
            from repro.defense.reputation import update_reputation
            agg, scores = aggregate_stacked_tree(
                buffer, robust_cfg, key=k_attack,
                active=state["defense"]["active"], with_scores=True,
                step=state["opt"]["step"])
            defense = update_reputation(state["defense"], scores,
                                        defense_cfg)
            extra_metrics = {
                "suspicion": scores,
                "reputation": defense["reputation"],
                "active": defense["active"],
                "q_hat": estimate_q(
                    scores, min_gap=defense_cfg.detector_min_gap),
            }
        else:
            agg = aggregate_stacked_tree(buffer, robust_cfg, key=k_attack,
                                         step=state["opt"]["step"])
        # Bounded-update rule: stale gradients make unbounded steps unstable,
        # so the server clips the aggregated update's global norm (standard
        # stale-synchronous stabilization).  This is a trust region, NOT a
        # defense: a corrupted aggregate direction (e.g. Mean under the
        # dimensional bitflip attack) stays corrupted after clipping — the
        # update budget is spent entirely on the attacked coordinates and
        # learning stalls, while robust rules yield clean directions that
        # clipping leaves essentially untouched.
        if acfg.update_clip:
            from repro.train.step import _tree_norm
            gn = _tree_norm(agg)
            scale = jnp.minimum(1.0, acfg.update_clip / jnp.maximum(gn, 1e-12))
            agg = jax.tree.map(lambda x: x * scale, agg)
        params, opt = apply_updates(opt_cfg, state["params"], agg,
                                    state["opt"])

        # workers refresh their stale copy with prob 1/tau
        refresh = jax.random.bernoulli(
            k_refresh, 1.0 / max(acfg.staleness, 1), (m,))
        worker_params = jax.tree.map(
            lambda wp, p: jnp.where(
                refresh.reshape((m,) + (1,) * p.ndim), p[None], wp),
            state["worker_params"], params)

        new_state = {"params": params, "opt": opt,
                     "worker_params": worker_params, "buffer": buffer}
        if defense is not None:
            new_state["defense"] = defense
        metrics = {"staleness_frac":
                   1.0 - jnp.mean(refresh.astype(jnp.float32)),
                   **extra_metrics}
        return new_state, metrics

    return init_state, jax.jit(step)


def run_async_training(model, batch_fn: Callable[[int], dict],
                       robust_cfg: RobustConfig, opt_cfg: OptConfig,
                       acfg: AsyncConfig, steps: int,
                       eval_fn: Optional[Callable] = None,
                       defense_cfg=None) -> list:
    """Deprecated driver shim: delegates to the ``async_ps`` topology
    (``repro.experiment``), which owns the loop this function used to.
    Returns the result's history records ({"step", "staleness_frac",
    ["eval"], ["q_hat"]}); new code should build a ``ScenarioSpec`` with
    ``topology="async_ps"`` and call ``run_experiment``."""
    from repro.experiment.runner import plan_from_parts
    from repro.experiment.topology import make_topology
    plan = plan_from_parts(
        model=model, batch_fn=batch_fn, robust_cfg=robust_cfg,
        opt_cfg=opt_cfg, num_workers=acfg.num_workers, steps=steps,
        seed=acfg.seed, topology="async_ps",
        topology_params={"staleness": acfg.staleness,
                         "update_clip": acfg.update_clip},
        eval_fn=eval_fn, defense_cfg=defense_cfg, record_every=10,
        telemetry_path=(defense_cfg.telemetry_path
                        if defense_cfg is not None else None))
    return make_topology("async_ps").run(plan).history
