"""Pytree checkpointing: flattened-path .npz + structure manifest (no orbax).

Dtypes (incl. bfloat16, stored as uint16 bit patterns) and the tree structure
round-trip exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, tree: Any, *, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, meta = {}, {"step": step, "dtypes": {}, "keys": []}
    for k, v in flat.items():
        arr = np.asarray(v)
        meta["dtypes"][k] = str(arr.dtype)
        meta["keys"].append(k)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            meta["dtypes"][k] = "bfloat16"
        arrays[k] = arr
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, step)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    flat_like = _flatten(like)
    out = {}
    for k in flat_like:
        arr = data[k]
        if meta["dtypes"][k] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        out[k] = jnp.asarray(arr)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = [  # rebuild in like's flatten order
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    return (jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys]),
            meta["step"])
