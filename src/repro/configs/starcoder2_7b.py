"""starcoder2-7b [dense] — GQA, RoPE, sliding-window attention.
[arXiv:2402.19173]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    window_pattern=(4096,),       # uniform sliding window
    rope_theta=1_000_000.0,
    citation="arXiv:2402.19173",
)
