"""granite-8b [dense] — llama-arch code model. [arXiv:2405.04324]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    window_pattern=(),            # full attention -> long_500k skipped
    rope_theta=10_000.0,
    citation="arXiv:2405.04324",
)
