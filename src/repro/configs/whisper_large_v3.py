"""whisper-large-v3 [audio] — enc-dec transformer backbone; the mel+conv
frontend is a STUB (input_specs supplies precomputed frame embeddings).
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,              # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encoder_layers=32,
    encoder_seq_len=1500,         # 30s audio after conv frontend (stub)
    frontend_dim=1280,
    window_pattern=(),            # full attention -> long_500k skipped
    citation="arXiv:2212.04356",
)
