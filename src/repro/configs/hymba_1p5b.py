"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.
Deviation noted in DESIGN.md: meta-tokens omitted; attention branch uses
uniform SWA (the SSM branch supplies global context, per the paper's design
argument).  [arXiv:2411.13676]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid=True,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    window_pattern=(1024,),       # SWA attention branch
    citation="arXiv:2411.13676",
)
