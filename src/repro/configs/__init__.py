"""Architecture registry: one module per assigned architecture (+ the paper's
own MLP/CNN experiment models), selectable via ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

_ARCH_MODULES = {
    "gemma3-27b": "repro.configs.gemma3_27b",
    "granite-8b": "repro.configs.granite_8b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
}


def get_arch(name: str) -> ArchConfig:
    reduced = name.endswith("-reduced")
    base = name[: -len("-reduced")] if reduced else name
    if base not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    cfg = importlib.import_module(_ARCH_MODULES[base]).CONFIG
    return cfg.reduced() if reduced else cfg


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_arch(n) for n in list_archs()}
