"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,                       # no separate FFN: the mamba block is the layer
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    citation="arXiv:2405.21060",
)
