"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2 backbone.
[arXiv:2404.16821]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    window_pattern=(),            # full attention -> long_500k skipped
    num_patches=256,              # patch embeddings per sample (stub ViT)
    vit_dim=3200,                 # InternViT-6B output dim
    rope_theta=1_000_000.0,
    citation="arXiv:2404.16821",
)
