"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.

Assignment note: the line reads "MoE 64e top-6 ... 2 shared+160 routed top-6";
160 routed belongs to full DeepSeek-V2 — the Lite model (and the leading
"64e") has 64 routed experts, which we follow.  [arXiv:2405.04434]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                    # per-expert FFN dim
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    head_dim=192,                 # nope + rope
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    rope_theta=10_000.0,
    citation="arXiv:2405.04434",
)
