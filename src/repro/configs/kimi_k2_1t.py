"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 routed experts top-8.
[arXiv:2501.kimi2 per assignment]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                    # per-expert FFN dim
    vocab_size=163840,
    num_experts=384,
    num_experts_per_tok=8,
    num_shared_experts=1,
    rope_theta=50_000.0,
    citation="arXiv:2501.kimi2",
)
