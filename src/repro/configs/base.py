"""Architecture + input-shape configuration schema."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture.  Fields default to "off"; each family uses a subset."""
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads

    # --- attention flavour ---
    # per-layer window pattern, repeated over the stack: each entry is a
    # sliding-window size or None (global).  () => all-global.
    window_pattern: Tuple[Optional[int], ...] = ()
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    use_post_norms: bool = False      # gemma2-style post-block RMSNorm
    tie_embeddings: bool = False

    # --- MLA (deepseek-style multi-head latent attention) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    num_experts: int = 0              # routed experts (0 => dense FFN)
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0                # d_state (0 => no ssm)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # --- hybrid (hymba): parallel attention + ssm heads in each layer ---
    hybrid: bool = False

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq_len: int = 0          # frames after the (stubbed) conv frontend
    frontend_dim: int = 0             # embedding dim the stub frontend emits

    # --- VLM ---
    num_patches: int = 0              # patch embeddings prepended per sample
    vit_dim: int = 0                  # stub vision encoder output dim

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    citation: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and not self.hybrid

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode memory: SSM/hybrid, or every attention layer
        windowed OR the arch mixes windowed layers with O(seq)-decode global
        layers (gemma-style) — what we exclude is *pure* full attention."""
        if self.ssm_state > 0:
            return True
        return bool(self.window_pattern) and any(
            w is not None for w in self.window_pattern)

    def layer_windows(self) -> Tuple[Optional[int], ...]:
        """Expanded per-layer window sizes (len == num_layers)."""
        if not self.window_pattern:
            return (None,) * self.num_layers
        reps = -(-self.num_layers // len(self.window_pattern))
        return (self.window_pattern * reps)[: self.num_layers]

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/flavour, tiny dims (<=2 layers,
        d_model<=512, <=4 experts)."""
        pat = self.window_pattern
        if pat:
            pat = tuple((min(w, 16) if w else None) for w in pat[:2])
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            window_pattern=pat,
            kv_lora_rank=min(self.kv_lora_rank, 64) if self.use_mla else 0,
            qk_rope_head_dim=32 if self.use_mla else self.qk_rope_head_dim,
            qk_nope_head_dim=32 if self.use_mla else self.qk_nope_head_dim,
            v_head_dim=64 if self.use_mla else self.v_head_dim,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 32),
            frontend_dim=min(self.frontend_dim, 256) if self.frontend_dim else 0,
            num_patches=min(self.num_patches, 8),
            vit_dim=min(self.vit_dim, 128) if self.vit_dim else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
