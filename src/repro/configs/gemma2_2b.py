"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window_pattern=(4096, None),  # alternating local:global
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norms=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    citation="arXiv:2408.00118",
)
