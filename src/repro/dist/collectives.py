"""Layout-agnostic collective helpers for code running inside ``shard_map``.

These are the building blocks of both robust-aggregation layouts
(DESIGN.md §2) and are shared by ``core/robust.py``, the Pallas kernels'
distributed drivers, serving, and the benchmarks:

  * :func:`gather_workers` — replicated layout: rebuild the full (m, D)
    worker matrix on every device;
  * :func:`all_to_all_scatter` / :func:`gather_slices` — sharded layout:
    re-tile the worker matrix so each device owns an (m, D/m) dimension
    slice, and the inverse rebuild of the aggregated vector;
  * :func:`axis_size` / :func:`worker_slice_index` — joint-axis geometry
    (the multi-pod ``("pod", "data")`` worker role is a flattened product
    of mesh axes, not a single named axis).

All functions take ``worker_axes`` as an ordered sequence of mesh axis
names; sequencing the per-axis collectives (instead of one multi-axis call)
keeps each step a supported tiled collective on every jax version and maps
onto the hierarchical ICI/DCN topology (intra-pod first, pod axis last).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def axis_size(names: Sequence[str]) -> int:
    """Product of the sizes of mesh axes ``names`` (inside shard_map)."""
    size = 1
    for n in names:
        size *= jax.lax.axis_size(n)
    return size


def gather_workers(x: jax.Array, worker_axes: Sequence[str]) -> jax.Array:
    """all_gather a (D,) local vector over worker axes -> (m_total, D)."""
    g = x[None]
    for name in reversed(worker_axes):
        g = jax.lax.all_gather(g, name, axis=0, tiled=True)
    return g


def all_to_all_scatter(x: jax.Array,
                       worker_axes: Sequence[str]) -> jax.Array:
    """Re-tile a (D,) local vector into (m_total, D/m_total) per device.

    Sequential tiled all_to_all over each worker axis: split the dimension
    slice, concatenate received blocks along the worker axis (DESIGN.md §2).
    """
    m_total = axis_size(worker_axes)
    d = x.shape[0]
    assert d % m_total == 0, f"flat dim {d} not divisible by m={m_total}"
    first = worker_axes[0]
    m0 = jax.lax.axis_size(first)
    u = x.reshape(m0, d // m0)
    u = jax.lax.all_to_all(u, first, split_axis=0, concat_axis=0, tiled=True)
    for name in worker_axes[1:]:
        # split the dim axis, concat along the worker axis
        u = jax.lax.all_to_all(u, name, split_axis=1, concat_axis=0,
                               tiled=True)
    return u  # (m_total, d // m_total)


def gather_slices(v: jax.Array, worker_axes: Sequence[str]) -> jax.Array:
    """Inverse of the dim-sharding of :func:`all_to_all_scatter` for the
    aggregated (D/m_total,) slice -> (D,)."""
    for name in reversed(worker_axes[1:]):
        v = jax.lax.all_gather(v, name, axis=0, tiled=True)
    v = jax.lax.all_gather(v, worker_axes[0], axis=0, tiled=True)
    return v


def worker_slice_index(worker_axes: Sequence[str]) -> jax.Array:
    """Linearized index of this device along the joint worker axes."""
    idx = jnp.int32(0)
    for name in worker_axes:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def psum_axes(x: jax.Array, names: Sequence[str]) -> jax.Array:
    """Sequential psum over ``names`` — a value can be varying over some
    axes and invariant over others, which a single multi-axis psum rejects
    under replication checking."""
    for name in names:
        x = jax.lax.psum(x, name)
    return x
