"""jax version compatibility for the sharding subsystem.

The repo targets the modern jax sharding surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.sharding.get_abstract_mesh``) but must run on the
pinned jax 0.4.37, where those names either do not exist or live under
``jax.experimental``.  :func:`install` back-fills the missing names onto the
``jax`` namespace from their 0.4-era equivalents:

  * ``jax.shard_map``            -> ``jax.experimental.shard_map.shard_map``
    (the modern ``check_vma`` kwarg maps onto the old ``check_rep``);
  * ``jax.set_mesh(mesh)``       -> the legacy mesh context manager
    (``with mesh:``), which is what resolves bare ``PartitionSpec``s inside
    ``with_sharding_constraint`` on 0.4;
  * ``jax.make_mesh``            -> accepts and ignores ``axis_types``
    (0.4 meshes are always fully Auto);
  * ``jax.sharding.AxisType``    -> a stand-in enum (Auto/Explicit/Manual);
  * ``jax.sharding.get_abstract_mesh`` -> the ambient legacy mesh from
    ``jax.interpreters.pxla.thread_resources`` (an empty ``Mesh()`` when no
    mesh context is active, matching the modern empty AbstractMesh).

Every shim is installed only when the attribute is missing, so on a modern
jax this module is a no-op.  ``repro/__init__.py`` calls :func:`install` at
package import time, which makes the shims visible to test subprocesses that
``import repro.<anything>`` before touching the modern API.
"""
from __future__ import annotations

import enum
import functools

import jax


def _ambient_mesh():
    """The legacy (0.4-era) ambient mesh: set by ``with mesh:`` contexts."""
    from jax.interpreters import pxla
    return pxla.thread_resources.env.physical_mesh


def _shard_map_compat(f=None, *, mesh, in_specs, out_specs, check_vma=True,
                      **kwargs):
    from jax.experimental.shard_map import shard_map as _shard_map
    if f is None:
        return functools.partial(_shard_map_compat, mesh=mesh,
                                 in_specs=in_specs, out_specs=out_specs,
                                 check_vma=check_vma, **kwargs)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, **kwargs)


class _SetMesh:
    """``jax.set_mesh(mesh)`` compat: usable as a context manager.

    On 0.4 the only ambient-mesh mechanism is the legacy mesh context
    (``Mesh.__enter__``), which both ``with_sharding_constraint(x, P(...))``
    and our :func:`get_abstract_mesh` shim read.
    """

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        return self.mesh.__exit__(*exc)


def install() -> None:
    """Back-fill modern jax sharding names missing from the pinned jax."""
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _ambient_mesh

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat

    if not hasattr(jax.lax, "axis_size"):
        # psum of the python literal 1 constant-folds to the axis size.
        jax.lax.axis_size = lambda name: jax.lax.psum(1, name)

    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _SetMesh

    # Signature inspection, NOT a probe call: make_mesh touches jax device
    # state, which must stay untouched until the caller has set XLA_FLAGS.
    import inspect
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
            del axis_types                       # 0.4 meshes are always Auto
            return _make_mesh(axis_shapes, axis_names, *args, **kw)

        jax.make_mesh = make_mesh
