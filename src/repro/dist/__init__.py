"""``repro.dist`` — SPMD sharding subsystem (DESIGN.md §3).

Mesh-role derivation and PartitionSpec rules (:mod:`repro.dist.sharding`),
layout-agnostic collectives for shard_map bodies
(:mod:`repro.dist.collectives`), and the jax-version compat layer
(:mod:`repro.dist.compat`, installed at ``repro`` package import).
"""
from repro.dist.collectives import (  # noqa: F401
    all_to_all_scatter, axis_size, gather_slices, gather_workers,
    psum_axes, worker_slice_index,
)
from repro.dist.sharding import (  # noqa: F401
    MODEL_AXIS_NAMES, cache_pspec, model_axes_of, param_pspec_fsdp,
    tree_pspecs, worker_axes_of,
)
