"""Mesh-role derivation and PartitionSpec rules for every model family.

The paper's parameter-server roles map onto mesh axes by NAME, not position
(DESIGN.md §3):

  * worker axes — the paper's m workers (``data``, plus ``pod`` in the
    multi-pod ``("pod", "data", "model")`` mesh): per-worker gradients are
    stacked over these axes and robust-aggregated across them;
  * model axes — tensor-parallel sharding of the parameters themselves
    (``model``); Krum-family distances psum over them so vector-wise
    selection sees full-vector geometry.

``tree_pspecs`` turns a parameter / optimizer-state / gradient pytree into a
matching pytree of ``PartitionSpec`` using name+shape rules that cover all
families in ``models/`` (dense GQA, MLA, MoE, Mamba2-SSD, hybrid, enc-dec):
Megatron-style column/row parallelism over the model axes, with replication
as the safe fallback whenever a dimension does not divide.  ``leaf_rule``
overrides the per-leaf decision (``param_pspec_fsdp`` is the FSDP rule used
by the streaming dry-run mode); ``cache_pspec`` is the KV-cache analogue
used by ``serve/engine.py`` and the decode dry-run.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

# Axis names playing the tensor-parallel role; everything else is a worker
# (data-parallel) axis.  Order within each role follows mesh.axis_names.
MODEL_AXIS_NAMES = frozenset({"model", "tensor", "tp", "mp"})

# Worker-role axis names the meshes in this repo actually use.
WORKER_AXIS_NAMES = frozenset({"data", "pod"})

# The complete mesh-axis vocabulary.  ``repro.analysis`` (rule AXIS001)
# pins every collective's axis-name literal to this set, so a new axis
# role must be added HERE before any psum/all_gather can name it.
AXIS_VOCAB = MODEL_AXIS_NAMES | WORKER_AXIS_NAMES


def worker_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes playing the paper's worker role, e.g. ``("data",)`` or
    ``("pod", "data")`` on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a not in MODEL_AXIS_NAMES)


def model_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Tensor-parallel mesh axes (``("model",)`` on the standard meshes)."""
    return tuple(a for a in mesh.axis_names if a in MODEL_AXIS_NAMES)


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# Linears whose OUTPUT features are model-sharded (column parallel) vs whose
# INPUT features are (row parallel — they consume column-parallel outputs).
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg",            # attention / GLU in-projections
    "wkv_a", "wk_rope", "wk_b", "wv_b",      # MLA projections
    "in_proj", "fc1", "router", "lm_head",   # SSM / VLM / head
})
_ROW_PARALLEL = frozenset({"wo", "out_proj", "fc2"})


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _tp_dim(names: Tuple[str, ...], ndim: int) -> Optional[int]:
    """Which dim of this leaf is model-sharded (None = replicate).

    Works on trailing path names so the same rules cover bare params,
    optimizer-state copies (``mu/.../wq/w``), and scan-stacked layer blocks
    (leading period dim shifts real dims to the END — hence negative dims).
    """
    if ndim < 2:
        return None
    leaf_name = names[-1] if names else ""
    owner = names[-2] if len(names) >= 2 else ""
    if leaf_name == "w":                       # a C.init_linear leaf
        if owner in _ROW_PARALLEL:
            return ndim - 2                    # contraction (input) features
        if owner in _COL_PARALLEL:
            return ndim - 1                    # output features
        return ndim - 1
    if leaf_name == "table":                   # embedding: shard the vocab
        return ndim - 2
    if leaf_name in ("moe_wi", "moe_wg"):      # (..., E, d, f): shard f
        return ndim - 1
    if leaf_name == "moe_wo":                  # (..., E, f, d): shard f
        return ndim - 2
    if leaf_name == "conv_w":                  # (width, channels): shard ch
        return ndim - 1
    if leaf_name == "scale" or ndim < 2:       # norms etc.
        return None
    return ndim - 1                            # unknown matrices: try last


def tree_pspecs(tree, mesh: Mesh,
                leaf_rule: Optional[Callable] = None):
    """PartitionSpec pytree matching ``tree`` (arrays or ShapeDtypeStructs).

    ``leaf_rule(name, leaf, mesh) -> PartitionSpec | None`` overrides the
    default tensor-parallel rule per leaf (``name`` is the "/"-joined path);
    returning None falls through to the default.
    """
    model_axes = model_axes_of(mesh)
    tp = _axes_size(mesh, model_axes)

    def spec_of(path, leaf):
        names = _path_names(path)
        if leaf_rule is not None:
            override = leaf_rule("/".join(names), leaf, mesh)
            if override is not None:
                return override
        shape = tuple(leaf.shape)
        dim = _tp_dim(names, len(shape))
        if (dim is None or tp <= 1 or shape[dim] % tp
                or shape[dim] < tp):
            return P()
        spec = [None] * len(shape)
        spec[dim] = model_axes if len(model_axes) > 1 else model_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_of, tree)


def param_pspec_fsdp(name: str, leaf, mesh: Mesh) -> Optional[P]:
    """FSDP leaf rule: fully shard each leaf over the joint (worker, model)
    device set, falling back to progressively smaller axis groups until one
    divides — the O(params/devices) memory mode used by the streaming
    dry-run (``--mode streaming``) for 1T-scale archs."""
    del name
    shape = tuple(leaf.shape)
    if not shape:
        return P()
    axes = worker_axes_of(mesh) + model_axes_of(mesh)
    # Longest suffix-group first (drops the coarsest axes first: a pure
    # 'model' group is the plain TP fallback), largest dims first.
    groups = [axes[i:] for i in range(len(axes))]
    groups += [(a,) for a in axes[:-1]]
    dims = sorted(range(len(shape)), key=lambda d: -shape[d])
    for group in groups:
        size = _axes_size(mesh, group)
        if size <= 1:
            continue
        for d in dims:
            if shape[d] % size == 0 and shape[d] >= size:
                spec = [None] * len(shape)
                spec[d] = group if len(group) > 1 else group[0]
                return P(*spec)
    return P()


# ---------------------------------------------------------------------------
# KV-cache rule (serve/engine.py + decode dry-run)
# ---------------------------------------------------------------------------

def cache_pspec(path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one KV-cache leaf.

    Caches are batch-major — attention ``k``/``v``: (B, T, Kv, hd); MLA
    latents: (B, T, rank); Mamba conv/SSD states: (B, ...) — except under
    the period-scanned ``blocks`` subtree, which prepends an (n_periods,)
    dim.  The request batch shards over the worker axes (each "server"
    owns a slice of the traffic) and GQA KV heads shard over the model
    axes when they divide.
    """
    names = _path_names(path)
    shape = tuple(leaf.shape)
    offset = 1 if names and names[0] == "blocks" else 0
    spec = [None] * len(shape)
    wa = worker_axes_of(mesh)
    m = _axes_size(mesh, wa)
    if m > 1 and len(shape) > offset and shape[offset] % m == 0:
        spec[offset] = wa if len(wa) > 1 else wa[0]
    model_axes = model_axes_of(mesh)
    tp = _axes_size(mesh, model_axes)
    head_dim = offset + 2
    if (tp > 1 and names and names[-1] in ("k", "v")
            and len(shape) == offset + 4 and shape[head_dim] % tp == 0):
        spec[head_dim] = model_axes if len(model_axes) > 1 else model_axes[0]
    return P(*spec)
