"""`ScenarioSpec` — one frozen, JSON-round-trippable description of an
entire experiment (DESIGN.md §9).

The paper's claims are about *scenarios*: rule × attack × q × batch-size
grids (Figs. 2-4), and the follow-up papers add more attack and rule axes.
Before this module the repo had no first-class scenario object — sync,
async, and streaming training were three divergent driver APIs and every
benchmark/example/CLI re-wired model × data × rule × attack × defense ×
mesh by hand.  ``ScenarioSpec`` is that wiring as *data*:

  spec = ScenarioSpec(
      topology="sync_ps",
      model=ModelSpec(kind="mlp"),
      data=DataSpec(kind="classification", dim=64),
      robust=RobustConfig(rule="phocas", b=6),
      attack=AttackConfig(name="gaussian", num_byzantine=6),
      num_workers=20, steps=100)
  result = run_experiment(spec)             # repro.experiment.runner

Design rules:

* every field is a plain value or one of the existing serializable configs
  (``RobustConfig``/``AttackConfig``/``DefenseConfig``/``OptConfig``), so a
  spec round-trips **bit-identically** through ``to_json``/``from_json``
  (tuples come back as tuples, nested configs as their dataclasses);
* ``validate()`` checks the spec against the rule/attack/topology registry
  *metadata* (``supports_streaming``, ``emits_scores``, ``uses_b``,
  ``step_aware``, mesh support, ...) at spec-build time, so a bad cell in a
  1000-cell sweep fails with an actionable message before any model is
  built or any step jitted;
* the attack is a first-class axis: ``spec.attack`` lives NEXT TO
  ``spec.robust`` (grid sweeps replace one field), and resolution injects
  it into the effective ``RobustConfig``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.core.attacks import AttackConfig
from repro.core.robust import RobustConfig
from repro.defense.reputation import DefenseConfig
from repro.optim.optimizers import OptConfig

SCHEDULES = ("", "constant", "cosine_decay", "warmup_cosine")


class SpecError(ValueError):
    """A scenario failed validation (actionable message, raised pre-run)."""


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What to train: the paper's MLP/CNN experiment models, or any
    architecture from the ``repro.configs`` zoo (``kind="arch"``)."""
    kind: str = "mlp"             # mlp | cnn | arch
    arch: str = ""                # configs.get_arch id (kind="arch")
    dims: Tuple[int, ...] = ()    # MLP layer dims; () = (dim, 128, 128, C)
    cnn_size: int = 16            # CNN input is (size, size, channels)
    cnn_channels: int = 3
    remat: str = "none"           # activation remat policy (arch models)


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """What to train on: the Gaussian-mixture classification substrate of
    the paper's experiments, or the bigram TokenStream for the arch zoo."""
    kind: str = "classification"  # classification | tokens
    dim: int = 64                 # feature dim (classification)
    num_classes: int = 10
    noise: float = 0.8
    seq_len: int = 64             # tokens
    batch_per_worker: int = 20    # global batch = num_workers * this
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One experiment, as declarative data.  See the module docstring."""
    name: str = "scenario"
    topology: str = "sync_ps"     # any @register_topology plugin
    topology_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    robust: RobustConfig = dataclasses.field(default_factory=RobustConfig)
    attack: AttackConfig = dataclasses.field(default_factory=AttackConfig)
    defense: Optional[DefenseConfig] = None
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    schedule: str = ""            # lr schedule plugin (repro.optim.schedules)
    schedule_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    num_workers: int = 20
    steps: int = 100
    seed: int = 0
    mesh: str = ""                # "DxM" device mesh (sync_ps only)
    log_every: int = 0            # history/eval cadence; 0 = steps//20
    checkpoint_path: str = ""     # "" = checkpointing off
    checkpoint_every: int = 0
    telemetry_path: str = ""      # JSONL sink ("" = off)

    # -- resolution helpers ------------------------------------------------

    def effective_attack(self) -> AttackConfig:
        """The scenario's attack axis (``attack`` wins; a legacy attack
        embedded in ``robust`` is honored when ``attack`` is clean)."""
        if self.attack.name not in ("none", ""):
            return self.attack
        return self.robust.attack

    def effective_robust(self) -> RobustConfig:
        """``robust`` with the scenario's attack axis injected."""
        return dataclasses.replace(self.robust, attack=self.effective_attack())

    def record_every(self) -> int:
        return self.log_every if self.log_every > 0 else max(
            self.steps // 20, 1)

    # -- JSON round-trip ---------------------------------------------------

    def to_dict(self) -> dict:
        return _encode(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        return _decode_dataclass(cls, d)

    def to_json(self) -> str:
        """Canonical JSON (sorted keys, 2-space indent) — two specs are
        equal iff their ``to_json`` strings are byte-identical."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "ScenarioSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- validation --------------------------------------------------------

    def validate(self) -> "ScenarioSpec":
        """Check this spec against the rule/attack/topology registries.

        Raises :class:`SpecError` with an actionable message (what is wrong
        AND what the valid choices are) — the point is to fail a bad sweep
        cell at spec-build time, not 40 minutes into the run.  Returns
        ``self`` so call sites can chain ``spec.validate()``.
        """
        from repro.core import registry
        from repro.experiment.topology import make_topology

        if self.steps < 1:
            raise SpecError(f"steps must be >= 1, got {self.steps}")
        m = self.num_workers
        if m < 2:
            raise SpecError(f"num_workers must be >= 2, got {m}")
        if self.data.batch_per_worker < 1:
            raise SpecError("data.batch_per_worker must be >= 1, got "
                            f"{self.data.batch_per_worker}")

        # model/data consistency
        if self.model.kind not in ("mlp", "cnn", "arch"):
            raise SpecError(f"model.kind {self.model.kind!r} unknown; "
                            "valid: mlp | cnn | arch")
        if self.data.kind not in ("classification", "tokens"):
            raise SpecError(f"data.kind {self.data.kind!r} unknown; "
                            "valid: classification | tokens")
        if self.model.kind == "arch":
            if not self.model.arch:
                raise SpecError("model.kind='arch' needs model.arch "
                                "(see repro.configs.list_archs())")
            if self.data.kind != "tokens":
                raise SpecError("arch models train on data.kind='tokens', "
                                f"got {self.data.kind!r}")
            from repro.configs import get_arch
            try:
                get_arch(self.model.arch)
            except KeyError as e:
                raise SpecError(str(e)) from None
        else:
            if self.data.kind != "classification":
                raise SpecError(f"model.kind={self.model.kind!r} trains on "
                                "data.kind='classification', got "
                                f"{self.data.kind!r}")
        if self.model.kind == "cnn":
            want = self.model.cnn_size ** 2 * self.model.cnn_channels
            if self.data.dim != want:
                raise SpecError(
                    f"cnn model needs data.dim == cnn_size^2 * cnn_channels "
                    f"= {want}, got {self.data.dim}")
        if self.model.kind == "mlp" and self.model.dims:
            if self.model.dims[0] != self.data.dim:
                raise SpecError(f"model.dims[0]={self.model.dims[0]} must "
                                f"equal data.dim={self.data.dim}")
            if self.model.dims[-1] != self.data.num_classes:
                raise SpecError(
                    f"model.dims[-1]={self.model.dims[-1]} must equal "
                    f"data.num_classes={self.data.num_classes}")

        # rule + parameters against registry metadata
        try:
            rule_cls = registry.get_rule(self.robust.rule)
            registry.resolve_backend(rule_cls, self.robust.backend)
        except ValueError as e:
            raise SpecError(str(e)) from None
        bmax = (m + 1) // 2 - 1
        if rule_cls.uses_b and not 0 <= self.robust.b <= bmax:
            raise SpecError(
                f"rule {self.robust.rule!r} needs 0 <= b <= (m+1)//2-1 = "
                f"{bmax} for m={m} workers, got b={self.robust.b}")
        if rule_cls.uses_q and not 0 <= self.robust.q <= m - 3:
            raise SpecError(
                f"rule {self.robust.rule!r} needs 0 <= q <= m-3 = {m - 3} "
                f"(Krum selection needs m-q-2 > 0), got q={self.robust.q}")

        # attack axis
        if (self.attack.name not in ("none", "")
                and self.robust.attack.name not in ("none", "")):
            raise SpecError(
                "both spec.attack and spec.robust.attack are set "
                f"({self.attack.name!r} vs {self.robust.attack.name!r}); "
                "the scenario's attack axis is spec.attack — leave "
                "robust.attack at its default")
        atk = self.effective_attack()
        if atk.name not in ("none", ""):
            try:
                registry.get_attack_spec(atk.name)
            except ValueError as e:
                raise SpecError(str(e)) from None

        # defense
        if self.defense is not None:
            if self.robust.rule not in registry.score_rules():
                raise SpecError(
                    f"defense needs a score-emitting rule (emits_scores); "
                    f"{self.robust.rule!r} is not one of "
                    f"{registry.score_rules()}")
            if self.defense.adapt_b and not (rule_cls.uses_b
                                             or rule_cls.uses_q):
                raise SpecError(
                    f"defense.adapt_b tunes the rule's b/q, but rule "
                    f"{self.robust.rule!r} consumes neither")

        # optimizer / schedule
        if not isinstance(self.opt.lr, (int, float)):
            raise SpecError("spec.opt.lr must be a number; express "
                            "schedules via spec.schedule + schedule_params "
                            f"(valid: {SCHEDULES[1:]})")
        if self.schedule not in SCHEDULES:
            raise SpecError(f"unknown schedule {self.schedule!r}; "
                            f"valid: {SCHEDULES[1:]}")

        # mesh shape (topology support is the topology's check)
        if self.mesh:
            d, _ = parse_mesh(self.mesh)
            if d != m:
                raise SpecError(
                    f"mesh={self.mesh!r} has a data axis of {d} but "
                    f"num_workers={m}; the mesh data axis plays the worker "
                    "role and the two must agree")

        # topology existence + its own metadata checks
        try:
            topo = make_topology(self.topology)
        except ValueError as e:
            raise SpecError(str(e)) from None
        topo.validate_spec(self)
        return self


def parse_mesh(mesh: str) -> Tuple[int, int]:
    """Parse a ``"DxM"`` mesh string into (data, model) axis sizes."""
    try:
        d, mm = (int(x) for x in mesh.split("x"))
        if d < 1 or mm < 1:
            raise ValueError
    except ValueError:
        raise SpecError(f"mesh must look like '4x2' (data x model), "
                        f"got {mesh!r}") from None
    return d, mm


# ---------------------------------------------------------------------------
# JSON codec: nested dataclasses <-> plain dicts, tuples <-> lists
# ---------------------------------------------------------------------------

# Field-name -> dataclass type for every nested config in the spec tree
# (names are unique across the tree, so one flat table suffices; note
# RobustConfig's own ``attack`` field is covered by the same entry).
_NESTED_FIELDS = {
    "model": ModelSpec,
    "data": DataSpec,
    "robust": RobustConfig,
    "attack": AttackConfig,
    "defense": DefenseConfig,
    "opt": OptConfig,
}


def _encode(v):
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {f.name: _encode(getattr(v, f.name))
                for f in dataclasses.fields(v)}
    if isinstance(v, (list, tuple)):
        return [_encode(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _encode(x) for k, x in v.items()}
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    raise SpecError(
        f"value {v!r} of type {type(v).__name__} is not JSON-serializable; "
        "scenario specs hold plain data only (callables like lr schedules "
        "are expressed by name via spec.schedule)")


def _decode_value(v):
    if isinstance(v, list):
        return tuple(_decode_value(x) for x in v)
    return v


def _decode_dataclass(cls, d):
    if d is None:
        return None
    if not isinstance(d, dict):
        raise SpecError(f"expected a JSON object for {cls.__name__}, "
                        f"got {type(d).__name__}")
    valid = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - valid)
    if unknown:
        raise SpecError(f"unknown field(s) {unknown} for {cls.__name__}; "
                        f"valid fields: {sorted(valid)}")
    kwargs = {}
    for name, v in d.items():
        if name in _NESTED_FIELDS and isinstance(v, (dict, type(None))):
            kwargs[name] = _decode_dataclass(_NESTED_FIELDS[name], v)
        elif name in ("topology_params", "schedule_params"):
            kwargs[name] = dict(v) if v else {}
        else:
            kwargs[name] = _decode_value(v)
    return cls(**kwargs)
