"""Grid sweeps over :class:`ScenarioSpec` axes + scenario-level result
caching (DESIGN.md §9).

A sweep is the cartesian product of dotted-path axes over a base spec:

    specs = sweep(base, {"robust.rule": ["phocas", "trmean"],
                         "attack.num_byzantine": [0, 4, 8],
                         "num_workers": [20, 40]})

Each dotted path addresses a (possibly nested) spec field — frozen
dataclasses are rebuilt with ``dataclasses.replace`` along the path, dict
fields (``topology_params``, ``schedule_params``) get a key set — so the
grid is expressed against the same declarative surface ``run_experiment``
consumes, and every cell is ``validate()``-checked up front (a bad cell
fails before any cell runs).

Caching keys on the *content* of the spec: :func:`scenario_key` is the
SHA-256 of the canonical ``to_json()`` (sorted keys — byte-identical specs
iff equal), so :func:`run_cached` replays a previously-run cell from its
JSON summary instead of re-running it.  Cache hits return an
:class:`ExperimentResult` with ``params=None`` (params are not persisted —
the cache stores *summaries*, not checkpoints; use ``checkpoint_path`` for
weights).  ``benchmarks/bench_serve.py`` drives its load-mix grid through
this module.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, Iterable, List, Sequence

from repro.experiment.runner import ExperimentResult, run_experiment
from repro.experiment.spec import ScenarioSpec


def _replace_path(obj: Any, path: str, value: Any) -> Any:
    """Rebuild ``obj`` with the dotted ``path`` set to ``value`` —
    dataclasses via ``dataclasses.replace``, dicts via key assignment."""
    head, _, rest = path.partition(".")
    if isinstance(obj, dict):
        if not rest:
            return {**obj, head: value}
        if head not in obj:
            raise KeyError(f"dict field has no key {head!r} to descend into")
        return {**obj, head: _replace_path(obj[head], rest, value)}
    if not dataclasses.is_dataclass(obj):
        raise TypeError(f"cannot descend into {type(obj).__name__} "
                        f"at {path!r}")
    names = {f.name for f in dataclasses.fields(obj)}
    if head not in names:
        raise KeyError(f"{type(obj).__name__} has no field {head!r} "
                       f"(axes use spec paths like 'robust.rule')")
    if not rest:
        return dataclasses.replace(obj, **{head: value})
    return dataclasses.replace(
        obj, **{head: _replace_path(getattr(obj, head), rest, value)})


def apply_overrides(spec: ScenarioSpec,
                    overrides: Dict[str, Any]) -> ScenarioSpec:
    """One grid cell: ``spec`` with every dotted-path override applied."""
    for path, value in overrides.items():
        spec = _replace_path(spec, path, value)
    return spec


def sweep(base: ScenarioSpec, axes: Dict[str, Sequence[Any]],
          *, validate: bool = True,
          name_cells: bool = True) -> List[ScenarioSpec]:
    """Cartesian product of ``axes`` over ``base`` (insertion-ordered, last
    axis fastest).  Each cell's ``name`` gets a ``path=value`` suffix so
    telemetry/results stay attributable; ``validate=True`` (default) checks
    every cell before returning — the whole grid fails fast on one bad cell.
    """
    cells: List[Dict[str, Any]] = [{}]
    for path, values in axes.items():
        cells = [{**cell, path: v} for cell in cells for v in values]
    out: List[ScenarioSpec] = []
    for cell in cells:
        spec = apply_overrides(base, cell)
        if name_cells and cell:
            suffix = ",".join(f"{p.rsplit('.', 1)[-1]}={v}"
                              for p, v in cell.items())
            spec = dataclasses.replace(spec, name=f"{spec.name}[{suffix}]")
        if validate:
            spec.validate()
        out.append(spec)
    return out


def scenario_key(spec: ScenarioSpec) -> str:
    """Content hash of the canonical spec JSON — equal iff the scenarios
    are byte-identical under ``to_json()`` (sorted keys)."""
    return hashlib.sha256(spec.to_json().encode()).hexdigest()


def run_cached(spec: ScenarioSpec, cache_dir: str,
               runner=run_experiment, **runner_kwargs) -> ExperimentResult:
    """Run ``spec`` (via ``runner``), or replay its stored summary.

    The cache entry is ``<cache_dir>/<scenario_key>.json`` holding the full
    spec (provenance + collision check) plus history/final_metrics/
    wall_time.  On a hit the stored spec must round-trip to the same
    canonical JSON — a mismatch means a hash collision or a hand-edited
    file, and raises rather than silently returning the wrong scenario.
    """
    key = scenario_key(spec)
    path = os.path.join(cache_dir, f"{key}.json")
    if os.path.exists(path):
        with open(path) as f:
            entry = json.load(f)
        stored = ScenarioSpec.from_dict(entry["spec"])
        if stored.to_json() != spec.to_json():
            raise ValueError(
                f"cache entry {path} holds a different scenario "
                f"({stored.name!r}); delete it and re-run")
        return ExperimentResult(
            spec=stored, history=entry["history"], params=None,
            final_metrics=entry["final_metrics"],
            wall_time=entry["wall_time"])
    result = runner(spec, **runner_kwargs)
    os.makedirs(cache_dir, exist_ok=True)
    entry = {"key": key, "spec": spec.to_dict(),
             "history": result.history,
             "final_metrics": result.final_metrics,
             "wall_time": result.wall_time}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(entry, f, indent=2, sort_keys=True, default=_tolerant)
    os.replace(tmp, path)
    return result


def run_sweep(base: ScenarioSpec, axes: Dict[str, Sequence[Any]],
              *, cache_dir: str = "", runner=run_experiment,
              ) -> List[ExperimentResult]:
    """``sweep`` + execute: every cell through :func:`run_cached` when
    ``cache_dir`` is set, plain ``runner`` otherwise."""
    specs = sweep(base, axes)
    if cache_dir:
        return [run_cached(s, cache_dir, runner=runner) for s in specs]
    return [runner(s) for s in specs]


def _tolerant(obj: Any):
    """JSON fallback for numpy/jax scalars that leak into history records."""
    item = getattr(obj, "item", None)
    if callable(item):
        return item()
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        return tolist()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")
