"""Builtin topology plugins: the three training-loop shapes the repo grew
as divergent drivers, now behind one ``Topology.run(plan)`` contract.

* ``sync_ps``   — the paper's synchronous parameter server (one SPMD
  program; DESIGN.md §2), with optional device mesh, defense loop, and the
  adaptive-b experiment step (ROADMAP item a).
* ``async_ps``  — buffered-async PS with geometric staleness (the paper's
  stated future work; ``train/async_sgd.py`` is the jitted engine).
* ``streaming`` — memory-bounded sequential scan (``train/streaming.py``);
  O((2b+1)·|θ|) instead of O(m·|θ|), collusion attacks excluded by
  metadata.

Each topology drives the existing jitted step builders — the engines stay
where they were; what moved here is the *loop*: batching, telemetry,
history records, checkpointing, adaptation.  The deprecated driver shims
(``Trainer``, ``run_async_training``, ``run_streaming_training``) call
these same loops via ``plan_from_parts``, so legacy and spec-built runs
share one code path step-for-step.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.data.pipeline import make_worker_batches
from repro.experiment.runner import ExperimentResult, Plan
from repro.experiment.spec import SpecError
from repro.experiment.topology import Topology, register_topology
from repro.obs.metrics import make_recorder
from repro.optim.optimizers import init_opt_state
from repro.train.streaming import STREAMING_ATTACKS


def _mask_flips(rec, prev, now, stream: str):
    """Count active-mask transitions into ejection/readmission counters;
    returns the new mask (host list).  The ejection *timeline* lives in
    the JSONL records; these counters are the at-a-glance Prometheus
    view the same data."""
    now = [bool(x) for x in now]
    if prev is not None and len(prev) == len(now):
        ej = sum(1 for w, n in zip(prev, now) if w and not n)
        re = sum(1 for w, n in zip(prev, now) if n and not w)
        if ej:
            rec.count("ejections", ej, stream=stream)
        if re:
            rec.count("readmissions", re, stream=stream)
    return now


def _defense_gauges(rec, *, rule_name: str, m: int, q_hat: int,
                    b: int, q: int) -> None:
    """q̂ + Δ-resilience-margin gauges for one defended step.

    ``resilience_margin`` is the paper-level safety slack: how many more
    Byzantine workers the configured rule tolerates beyond the detector's
    current estimate (tolerance − q̂; negative means the run has left the
    rule's proven envelope).  ``delta_bound`` is the unit-variance Δ bound
    at (m, q̂, b) from core/bounds.py, when the theory defines one."""
    rule_meta = registry.get_rule(rule_name)
    tolerance = b if rule_meta.uses_b else q
    rec.gauge("q_hat", q_hat)
    rec.gauge("resilience_margin", tolerance - q_hat, rule=rule_name)
    from repro.defense.detector import _delta_bound
    bound = _delta_bound(rule_name, m, q_hat, b, 1.0)
    if bound is not None:
        rec.gauge("delta_bound_unit_var", bound, rule=rule_name)


def _profile_step_cost(rec, plan: Plan, step_fn, args) -> None:
    """One-shot FLOPs/bytes gauges for the compiled train step (AOT lower
    + compile — an extra compile, so gated on obs.profile_cost)."""
    if not (rec.metrics_enabled and plan.obs is not None
            and getattr(plan.obs, "profile_cost", False)):
        return
    from repro.obs.profile import compiled_cost
    for name, v in compiled_cost(step_fn, *args).items():
        rec.gauge(f"step_{name}", v)


@register_topology
class SyncPS(Topology):
    """The paper's synchronous PS loop (port of ``Trainer.run``)."""

    name = "sync_ps"
    supports_mesh = True
    supports_defense = True
    supports_adapt_b = True

    def run(self, plan: Plan, init_state=None) -> ExperimentResult:
        from repro.train.step import make_train_step, shard_params

        m = plan.num_workers
        robust_cfg = plan.robust_cfg
        dcfg = plan.defense_cfg
        rule_meta = registry.get_rule(robust_cfg.rule)

        def build_step(rc):
            return make_train_step(
                plan.model, robust_cfg=rc, opt_cfg=plan.opt_cfg,
                num_workers=m, mesh=plan.mesh, donate=False,
                defense_cfg=dcfg)

        step_fn = build_step(robust_cfg)
        if init_state is not None:
            params, opt_state, defense_state = init_state
        else:
            params = plan.model.init(jax.random.PRNGKey(plan.seed))
            if plan.mesh is not None:
                params = shard_params(params, plan.mesh)
            opt_state = init_opt_state(plan.opt_cfg, params)
            defense_state = None
            if dcfg is not None:
                from repro.defense.reputation import init_reputation
                defense_state = init_reputation(m)

        # adapt_b bookkeeping (ROADMAP item a): the detector's online q̂
        # feeds back into the rule's b/q.  Changing b changes the rule's
        # static selection windows, so each adaptation re-jits the step —
        # a host-side decision, made only after q̂ > current for
        # ``adapt_patience`` consecutive steps (noise hysteresis).
        adapt = dcfg is not None and dcfg.adapt_b
        bmax = (m + 1) // 2 - 1
        pending = 0

        key = jax.random.PRNGKey(plan.seed + 1)
        history: list = []
        metrics: dict = {}
        prev_active = None
        profiled_cost = False
        t0 = time.time()
        with make_recorder(plan.telemetry_path, plan.obs) as rec:
            for step in range(plan.steps):
                batch = make_worker_batches(plan.batch_fn(step), m)
                key, sk = jax.random.split(key)
                if defense_state is not None:
                    if not profiled_cost:
                        profiled_cost = True
                        _profile_step_cost(rec, plan, step_fn,
                                           (params, opt_state, batch, sk,
                                            defense_state))
                    with rec.span("train_step", step_num=step,
                                  rule=robust_cfg.rule) as sp:
                        (params, opt_state, defense_state, metrics) = \
                            sp.sync(step_fn(params, opt_state, batch, sk,
                                            defense_state))
                    rec.log("train", step,
                            loss=metrics["loss"],
                            grad_norm=metrics["grad_norm"],
                            suspicion=metrics["suspicion"],
                            reputation=metrics["reputation"],
                            active=metrics["active"],
                            q_hat=metrics["q_hat"])
                    if rec.metrics_enabled:
                        prev_active = _mask_flips(
                            rec, prev_active, metrics["active"], "train")
                        _defense_gauges(
                            rec, rule_name=robust_cfg.rule, m=m,
                            q_hat=int(metrics["q_hat"]), b=robust_cfg.b,
                            q=robust_cfg.q)
                else:
                    if not profiled_cost:
                        profiled_cost = True
                        _profile_step_cost(rec, plan, step_fn,
                                           (params, opt_state, batch, sk))
                    with rec.span("train_step", step_num=step,
                                  rule=robust_cfg.rule) as sp:
                        params, opt_state, metrics = sp.sync(step_fn(
                            params, opt_state, batch, sk))
                rec.count("steps", topology=self.name)

                if step % plan.record_every == 0 or step == plan.steps - 1:
                    row = {"step": step, "loss": float(metrics["loss"]),
                           "grad_norm": float(metrics["grad_norm"]),
                           "wall": time.time() - t0}
                    if "q_hat" in metrics:
                        row["q_hat"] = int(metrics["q_hat"])
                        row["n_active"] = int(jnp.sum(metrics["active"]))
                    if plan.eval_fn is not None:
                        row["eval"] = float(plan.eval_fn(params))
                    history.append(row)
                    if rec.metrics_enabled:
                        from repro.obs.profile import sample_into
                        sample_into(rec)
                    if plan.verbose:
                        msg = (f"step {step:5d}  loss {row['loss']:.4f}  "
                               f"gnorm {row['grad_norm']:.3e}")
                        if "q_hat" in row:
                            msg += (f"  qhat {row['q_hat']}  "
                                    f"active {row['n_active']}")
                        if "eval" in row:
                            msg += f"  eval {row['eval']:.4f}"
                        print(msg, flush=True)

                if (plan.checkpoint_path and plan.checkpoint_every and step
                        and step % plan.checkpoint_every == 0):
                    from repro.checkpoint.io import save_checkpoint
                    tree = {"params": params, "opt": opt_state}
                    if defense_state is not None:
                        tree["defense"] = defense_state
                    save_checkpoint(plan.checkpoint_path, tree, step=step)

                if adapt:
                    q_hat = int(metrics["q_hat"])
                    current = (robust_cfg.b if rule_meta.uses_b
                               else robust_cfg.q)
                    pending = pending + 1 if q_hat > current else 0
                    if pending >= dcfg.adapt_patience:
                        new_b = (min(q_hat, bmax) if rule_meta.uses_b
                                 else robust_cfg.b)
                        new_q = (min(max(q_hat, robust_cfg.q), m - 3)
                                 if rule_meta.uses_q else robust_cfg.q)
                        pending = 0
                        # q̂ beyond the cap leaves b/q saturated — nothing
                        # to re-jit, and refiring every patience window
                        # would recompile an unchanged step forever.
                        if (new_b != robust_cfg.b
                                or new_q != robust_cfg.q):
                            robust_cfg = dataclasses.replace(
                                robust_cfg, b=new_b, q=new_q)
                            step_fn = build_step(robust_cfg)
                            history.append(
                                {"step": step, "adapted_b": new_b,
                                 "adapted_q": new_q, "q_hat": q_hat})
                            rec.log("adapt", step, b=new_b, q=new_q,
                                    q_hat=q_hat)
                            rec.count("adaptations")
                            if plan.verbose:
                                print(f"step {step:5d}  [adapt] "
                                      f"q_hat={q_hat} -> b={new_b} "
                                      f"q={new_q} (re-jit)", flush=True)
            wall = time.time() - t0
            rec.gauge("steps_per_sec", plan.steps / max(wall, 1e-9),
                      topology=self.name)

        return ExperimentResult(
            spec=plan.spec, history=history, params=params,
            opt_state=opt_state, defense_state=defense_state,
            final_metrics=_scalarize(metrics), robust_cfg=robust_cfg,
            wall_time=wall)


@register_topology
class AsyncPS(Topology):
    """Buffered-async PS (port of ``run_async_training``'s loop)."""

    name = "async_ps"
    supports_defense = True
    param_names = ("staleness", "update_clip")

    def run(self, plan: Plan, init_state=None) -> ExperimentResult:
        from repro.train.async_sgd import AsyncConfig, make_async_train_step

        m = plan.num_workers
        acfg = AsyncConfig(
            num_workers=m,
            staleness=int(plan.topology_params.get("staleness", 4)),
            update_clip=float(plan.topology_params.get("update_clip", 10.0)),
            seed=plan.seed)
        init_fn, step_fn = make_async_train_step(
            plan.model, robust_cfg=plan.robust_cfg, opt_cfg=plan.opt_cfg,
            acfg=acfg, defense_cfg=plan.defense_cfg)
        key = jax.random.PRNGKey(plan.seed)
        state = init_fn(key) if init_state is None else init_state
        history: list = []
        metrics: dict = {}
        prev_active = None
        t0 = time.time()
        with make_recorder(plan.telemetry_path, plan.obs) as rec:
            for i in range(plan.steps):
                batch = make_worker_batches(plan.batch_fn(i), m)
                with rec.span("async_step", step_num=i,
                              rule=plan.robust_cfg.rule) as sp:
                    state, metrics = sp.sync(step_fn(
                        state, batch, jax.random.fold_in(key, i)))
                rec.count("steps", topology=self.name)
                if plan.defense_cfg is not None:
                    rec.log("async", i,
                            staleness_frac=metrics["staleness_frac"],
                            suspicion=metrics["suspicion"],
                            reputation=metrics["reputation"],
                            active=metrics["active"],
                            q_hat=metrics["q_hat"])
                    if rec.metrics_enabled:
                        prev_active = _mask_flips(
                            rec, prev_active, metrics["active"], "async")
                        _defense_gauges(
                            rec, rule_name=plan.robust_cfg.rule, m=m,
                            q_hat=int(metrics["q_hat"]),
                            b=plan.robust_cfg.b, q=plan.robust_cfg.q)
                if i % plan.record_every == 0 or i == plan.steps - 1:
                    row = {"step": i, "staleness_frac":
                           float(metrics["staleness_frac"])}
                    if "q_hat" in metrics:
                        row["q_hat"] = int(metrics["q_hat"])
                    if plan.eval_fn is not None:
                        row["eval"] = float(plan.eval_fn(state["params"]))
                    history.append(row)
                    if plan.verbose and "eval" in row:
                        print(f"step {i:5d}  eval {row['eval']:.4f}",
                              flush=True)
            wall = time.time() - t0
            rec.gauge("steps_per_sec", plan.steps / max(wall, 1e-9),
                      topology=self.name)

        return ExperimentResult(
            spec=plan.spec, history=history, params=state["params"],
            opt_state=state["opt"], defense_state=state.get("defense"),
            final_metrics=_scalarize(metrics), robust_cfg=plan.robust_cfg,
            wall_time=wall)


@register_topology
class Streaming(Topology):
    """Memory-bounded scan (port of ``run_streaming_training``'s loop)."""

    name = "streaming"
    attack_allowlist = STREAMING_ATTACKS
    requires_streaming_rule = True

    def run(self, plan: Plan, init_state=None) -> ExperimentResult:
        from repro.train.streaming import make_streaming_train_step

        m = plan.num_workers
        step_fn = make_streaming_train_step(
            plan.model, robust_cfg=plan.robust_cfg, opt_cfg=plan.opt_cfg,
            num_workers=m)
        key = jax.random.PRNGKey(plan.seed)
        if init_state is not None:
            params, opt_state, _ = init_state
        else:
            params = plan.model.init(key)
            opt_state = init_opt_state(plan.opt_cfg, params)
        history: list = []
        metrics: dict = {}
        t0 = time.time()
        with make_recorder(plan.telemetry_path, plan.obs) as rec:
            for i in range(plan.steps):
                batch = make_worker_batches(plan.batch_fn(i), m)
                with rec.span("streaming_step", step_num=i,
                              rule=plan.robust_cfg.rule) as sp:
                    params, opt_state, metrics = sp.sync(step_fn(
                        params, opt_state, batch,
                        jax.random.fold_in(key, i)))
                rec.count("steps", topology=self.name)
                extra = ({"suspicion": metrics["suspicion"]}
                         if "suspicion" in metrics else {})
                rec.log("streaming", i, loss=metrics["loss"], **extra)
                if i % plan.record_every == 0 or i == plan.steps - 1:
                    row = {"step": i, "loss": float(metrics["loss"])}
                    if plan.eval_fn is not None:
                        row["eval"] = float(plan.eval_fn(params))
                    history.append(row)
                    if plan.verbose:
                        msg = f"step {i:5d}  loss {row['loss']:.4f}"
                        if "eval" in row:
                            msg += f"  eval {row['eval']:.4f}"
                        print(msg, flush=True)
            wall = time.time() - t0
            rec.gauge("steps_per_sec", plan.steps / max(wall, 1e-9),
                      topology=self.name)

        return ExperimentResult(
            spec=plan.spec, history=history, params=params,
            opt_state=opt_state, final_metrics=_scalarize(metrics),
            robust_cfg=plan.robust_cfg, wall_time=wall)


def _scalarize(metrics: dict) -> dict:
    """Final-step metrics with device scalars pulled to floats (per-worker
    vectors and other non-scalars are dropped — they live in telemetry)."""
    out = {}
    for k, v in metrics.items():
        try:
            arr = jnp.asarray(v)
        except TypeError:
            continue
        if arr.ndim == 0:
            out[k] = float(arr)
    return out


@register_topology
class Serve(Topology):
    """Serving as a scenario (DESIGN.md §11): Poisson arrivals through the
    continuous-batching paged engine (``repro.serve.ServeEngine``), with
    ``spec.robust`` selecting the logits-aggregation rule when k replicas
    serve each decode step and ``spec.attack`` corrupting
    ``num_byzantine`` of them (clamped to the replica trim bound).

    ``spec.steps`` caps engine iterations; history records carry queue
    depth and throughput; final metrics are the latency/throughput summary
    ``benchmarks/bench_serve.py`` aggregates over its load-mix grid.
    """

    name = "serve"
    supports_defense = True
    # Replica count lives here (NOT spec.num_workers, which is the training
    # fan-out and must stay >= 2); every key is read via a literal
    # topology_params.get(...) below so repro.analysis CONTRACT006 can
    # cross-check this tuple against the loop body.
    param_names = ("replicas", "max_slots", "max_seq_len", "block_tokens",
                   "num_requests", "arrival_rate", "prompt_len",
                   "max_new_tokens")
    # corrupt_replica injects Gaussian garbage parameters — the only fault
    # model the serving path simulates.
    attack_allowlist = ("gaussian",)

    def validate_spec(self, spec) -> None:
        super().validate_spec(spec)
        if spec.model.kind != "arch":
            raise SpecError("topology 'serve' decodes an arch-zoo model; "
                            "set model.kind='arch' (+ data.kind='tokens')")
        from repro.configs import get_arch
        from repro.models.stack import paged_supported
        if not paged_supported(get_arch(spec.model.arch)):
            raise SpecError(
                f"arch {spec.model.arch!r} is not paged-serving capable "
                "(SSM/hybrid/MLA/enc-dec/windowed layers); pick an "
                "all-global attention arch like 'granite-8b-reduced'")
        k = int(spec.topology_params.get("replicas", 1))
        if k > 1:
            bmax = (k + 1) // 2 - 1
            if not 0 <= spec.robust.b <= bmax:
                raise SpecError(
                    f"replicated decode with k={k} replicas needs "
                    f"0 <= robust.b <= (k+1)//2-1 = {bmax}, got "
                    f"b={spec.robust.b}")
            q = spec.effective_attack().num_byzantine
            if q > bmax:
                raise SpecError(
                    f"attack corrupts {q} replicas but k={k} replicated "
                    f"decode tolerates at most (k+1)//2-1 = {bmax}")

    def run(self, plan: Plan, init_state=None) -> ExperimentResult:
        import numpy as np
        from repro.serve import (RobustDecoder, ServeEngine, corrupt_replica,
                                 make_replicas)

        replicas = int(plan.topology_params.get("replicas", 1))
        max_slots = int(plan.topology_params.get("max_slots", 8))
        max_seq_len = int(plan.topology_params.get("max_seq_len", 128))
        block_tokens = int(plan.topology_params.get("block_tokens", 16))
        num_requests = int(plan.topology_params.get("num_requests", 16))
        # arrival_rate: requests per engine step (Poisson)
        arrival_rate = float(plan.topology_params.get("arrival_rate", 2.0))
        prompt_len = int(plan.topology_params.get("prompt_len", 8))
        max_new = int(plan.topology_params.get("max_new_tokens", 16))

        model = plan.model
        key = jax.random.PRNGKey(plan.seed)
        params = model.init(key) if init_state is None else init_state[0]

        decoder = None
        if replicas > 1:
            rc = plan.robust_cfg
            params = make_replicas(params, replicas)
            corrupt = rc.attack.num_byzantine if rc.attack.name == "gaussian" \
                else 0
            for i in range(corrupt):
                params = corrupt_replica(
                    params, replicas - 1 - i,
                    jax.random.fold_in(key, 1000 + i))
            decoder = RobustDecoder(
                rule=rc.rule, k=replicas, b=rc.b,
                defense=plan.defense_cfg, backend=rc.backend)

        history: list = []
        t0 = time.time()
        with make_recorder(plan.telemetry_path, plan.obs) as rec:
            engine = ServeEngine(
                model, params, max_slots=max_slots, max_seq_len=max_seq_len,
                block_tokens=block_tokens, decoder=decoder, telemetry=rec)

            # Deterministic Poisson arrivals in engine-step time.
            rng = np.random.default_rng(plan.seed)
            gaps = rng.exponential(1.0 / max(arrival_rate, 1e-9),
                                   num_requests)
            due = np.cumsum(gaps)
            prompts = rng.integers(0, model.cfg.vocab_size,
                                   (num_requests, prompt_len))
            submitted = 0
            produced = 0
            for i in range(plan.steps):
                while submitted < num_requests and due[submitted] <= i:
                    engine.submit(prompts[submitted].tolist(), max_new)
                    submitted += 1
                if submitted >= num_requests and not engine.scheduler.busy:
                    break
                produced += engine.step()
                if i % plan.record_every == 0:
                    history.append({
                        "step": i, "submitted": submitted,
                        "queued": engine.scheduler.queued,
                        "active": len(engine.scheduler.active),
                        "tokens": produced})
            engine.scheduler.retire_finished()

        wall = time.time() - t0
        done = engine.scheduler.completed
        lat = sorted(r.latency_ms() for r in done) or [0.0]
        ttft = sorted(r.first_token_ms() for r in done) or [0.0]
        pct = lambda xs, q: xs[min(len(xs) - 1,  # noqa: E731
                                   int(q * (len(xs) - 1) + 0.5))]
        metrics = {
            "completed": float(len(done)),
            "tokens": float(produced),
            "tokens_per_sec": produced / max(wall, 1e-9),
            "latency_p50_ms": pct(lat, 0.50),
            "latency_p99_ms": pct(lat, 0.99),
            "ttft_p50_ms": pct(ttft, 0.50),
            "engine_steps": float(engine.steps_run),
        }
        if decoder is not None:
            metrics["ejected_replicas"] = float(
                len(decoder.ejected_replicas()))
        history.append({"step": engine.steps_run, **metrics})

        return ExperimentResult(
            spec=plan.spec, history=history, params=params,
            final_metrics=metrics, robust_cfg=plan.robust_cfg,
            wall_time=wall)
