"""Topology plugin registry — how a scenario *executes* (DESIGN.md §9).

A topology is the training-loop shape: the paper's synchronous parameter
server, the buffered-async PS of its stated future work, the memory-bounded
streaming scan, or anything a plugin adds (hierarchical PS, gossip, ...).
Mirrors ``core/registry.py``: subclass :class:`Topology`, set the metadata
classvars, implement ``run``, decorate with :func:`register_topology`, and
the whole stack — ``run_experiment``, the launch CLI, benchmark sweeps, the
scenario-smoke CI matrix — enumerates the new topology automatically.

The metadata classvars drive *generic* spec validation
(:meth:`Topology.validate_spec`): which scenario features the loop supports
(device mesh, defense state, adaptive b), which attacks it can simulate
(streaming cannot host colluding adversaries), and which ``topology_params``
keys it consumes.  Validation runs at spec-build time with actionable
errors, replacing the mid-run ValueErrors the three legacy drivers threw.
"""
from __future__ import annotations

from typing import ClassVar, Dict, Optional, Tuple, Type

from repro.experiment.spec import ScenarioSpec, SpecError


class Topology:
    """Base class for registered training topologies.

    ``run(plan, init_state=None)`` executes the resolved scenario
    (:class:`repro.experiment.runner.Plan`) and returns an
    :class:`repro.experiment.runner.ExperimentResult`.  ``init_state``
    optionally injects pre-built ``(params, opt_state, defense_state)`` —
    the hook the deprecated ``Trainer`` shim uses to keep its restore/
    checkpoint surface working on top of the new path.
    """

    # --- metadata (override in subclasses) ---
    name: ClassVar[str]
    supports_mesh: ClassVar[bool] = False      # spec.mesh usable
    supports_defense: ClassVar[bool] = False   # spec.defense usable
    supports_adapt_b: ClassVar[bool] = False   # defense.adapt_b usable
    param_names: ClassVar[Tuple[str, ...]] = ()  # valid topology_params keys
    # None = every registered attack; otherwise the simulatable subset.
    attack_allowlist: ClassVar[Optional[Tuple[str, ...]]] = None
    requires_streaming_rule: ClassVar[bool] = False

    # --- generic metadata validation (subclasses may extend) ---

    def validate_spec(self, spec: ScenarioSpec) -> None:
        from repro.core import registry

        if spec.mesh and not self.supports_mesh:
            raise SpecError(
                f"topology {self.name!r} does not support a device mesh; "
                f"drop mesh={spec.mesh!r} or use one of "
                f"{[t for t in available_topologies() if get_topology(t).supports_mesh]}")
        if spec.defense is not None and not self.supports_defense:
            raise SpecError(
                f"topology {self.name!r} does not support the defense loop; "
                f"drop spec.defense or use one of "
                f"{[t for t in available_topologies() if get_topology(t).supports_defense]}")
        if (spec.defense is not None and spec.defense.adapt_b
                and not self.supports_adapt_b):
            raise SpecError(
                f"defense.adapt_b (online b/q re-tuning) is only available "
                f"on topologies "
                f"{[t for t in available_topologies() if get_topology(t).supports_adapt_b]}, "
                f"not {self.name!r}")
        unknown = sorted(set(spec.topology_params) - set(self.param_names))
        if unknown:
            raise SpecError(
                f"unknown topology_params {unknown} for topology "
                f"{self.name!r}; valid keys: {sorted(self.param_names)}")
        atk = spec.effective_attack().name.lower()
        if (atk not in ("none", "") and self.attack_allowlist is not None
                and atk not in self.attack_allowlist):
            raise SpecError(
                f"attack {atk!r} cannot be simulated on topology "
                f"{self.name!r} (supported: {self.attack_allowlist})")
        if self.requires_streaming_rule:
            if not registry.get_rule(spec.robust.rule).supports_streaming:
                raise SpecError(
                    f"topology {self.name!r} needs a streaming-capable rule "
                    f"(supports_streaming); {spec.robust.rule!r} is not one "
                    f"of {registry.streaming_rules()}")

    # --- execution (override) ---

    def run(self, plan, init_state=None):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_TOPOLOGIES: Dict[str, Type[Topology]] = {}


def register_topology(cls: Type[Topology]) -> Type[Topology]:
    """Class decorator: make ``cls`` reachable by name everywhere."""
    name = cls.name.lower()
    prev = _TOPOLOGIES.get(name)
    if prev is not None and prev is not cls:
        raise ValueError(f"topology {name!r} already registered by "
                         f"{prev.__module__}.{prev.__qualname__}")
    _TOPOLOGIES[name] = cls
    return cls


def _ensure_builtins() -> None:
    # Deferred: the builtin topologies import this module for the decorator.
    import repro.experiment.topologies  # noqa: F401


def available_topologies() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_TOPOLOGIES))


def get_topology(name: str) -> Type[Topology]:
    _ensure_builtins()
    key = name.lower()
    if key not in _TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}; "
                         f"have {sorted(_TOPOLOGIES)}")
    return _TOPOLOGIES[key]


def make_topology(name: str) -> Topology:
    return get_topology(name)()
