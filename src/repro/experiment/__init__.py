"""``repro.experiment`` — declarative scenarios + pluggable topologies
(DESIGN.md §9).

One frozen, JSON-round-trippable :class:`ScenarioSpec` describes an entire
experiment (model × data × optimizer × rule × attack × defense × mesh ×
topology), one :func:`run_experiment` entry point executes it, and
topologies are registry plugins exactly like rules and attacks — adding a
scenario axis is a one-file change, and every consumer (launch CLI,
benchmark grids, examples, CI smoke matrix) enumerates the same registry.
"""
from repro.experiment.runner import (  # noqa: F401
    ExperimentResult, Plan, plan_from_parts, resolve, run_experiment,
)
from repro.experiment.spec import (  # noqa: F401
    DataSpec, ModelSpec, ScenarioSpec, SpecError,
)
from repro.experiment.sweep import (  # noqa: F401
    apply_overrides, run_cached, run_sweep, scenario_key, sweep,
)
from repro.experiment.topology import (  # noqa: F401
    Topology, available_topologies, get_topology, make_topology,
    register_topology,
)
