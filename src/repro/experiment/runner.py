"""Scenario resolution + the single ``run_experiment(spec)`` entry point.

``resolve(spec)`` turns declarative data into the runtime bundle every
topology consumes (:class:`Plan`): the model, the batch function, the eval
closure, the device mesh, the effective ``RobustConfig`` (attack axis
injected), and the resolved optimizer (lr schedules bound by name).  The
spec is validated against the registries first, so every failure mode the
three legacy drivers surfaced mid-run — streaming-incapable rule, defense
on a score-less rule, bad mesh shape — fails here with an actionable
message before anything is jitted.

``run_experiment`` is the one training entry point: every path (launch
CLI, benchmarks, examples, scenario-smoke CI, the deprecated shims) goes
spec -> resolve -> topology plugin -> :class:`ExperimentResult`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.robust import RobustConfig
from repro.experiment.spec import ScenarioSpec, parse_mesh
from repro.experiment.topology import make_topology
from repro.optim.optimizers import OptConfig


@dataclasses.dataclass
class Plan:
    """A resolved scenario: everything a topology needs to run the loop.

    Built by :func:`resolve` from a validated spec, or directly by the
    deprecated driver shims (``Trainer``/``run_async_training``/
    ``run_streaming_training``) from their legacy arguments — which is what
    makes the shims thin delegations instead of parallel code paths.
    """
    spec: Optional[ScenarioSpec]
    topology: str
    topology_params: Dict[str, Any]
    model: Any
    batch_fn: Callable[[int], dict]
    eval_fn: Optional[Callable]
    robust_cfg: RobustConfig          # effective (attack axis injected)
    opt_cfg: OptConfig                # effective (schedule bound)
    defense_cfg: Any                  # DefenseConfig | None
    mesh: Any                         # jax Mesh | None
    num_workers: int
    steps: int
    seed: int
    record_every: int                 # history/eval cadence
    checkpoint_path: Optional[str]
    checkpoint_every: int
    telemetry_path: Optional[str]
    verbose: bool = False
    # Observability switches (repro.obs.ObsConfig | None).  Lives on the
    # Plan, not the spec: ScenarioSpec stays JSON-canonical, and whether a
    # run is instrumented is a property of the invocation, not the cell.
    obs: Any = None


@dataclasses.dataclass
class ExperimentResult:
    """What a topology returns: the trajectory plus the final state.

    ``history`` records land every ``record_every`` steps (and on the last
    step); their keys depend on the topology and on whether defense/eval
    are configured — see DESIGN.md §9.  ``robust_cfg`` is the *final*
    effective config (it differs from the spec's when ``defense.adapt_b``
    re-tuned b/q mid-run).
    """
    spec: Optional[ScenarioSpec]
    history: List[dict]
    params: Any
    opt_state: Any = None
    defense_state: Optional[dict] = None
    final_metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    robust_cfg: Optional[RobustConfig] = None
    wall_time: float = 0.0

    @property
    def final_loss(self) -> Optional[float]:
        for rec in reversed(self.history):
            if "loss" in rec:
                return rec["loss"]
        return None

    @property
    def final_eval(self) -> Optional[float]:
        for rec in reversed(self.history):
            if "eval" in rec:
                return rec["eval"]
        return None

    @property
    def eval_curve(self) -> List[tuple]:
        return [(r["step"], r["eval"]) for r in self.history if "eval" in r]


def resolve(spec: ScenarioSpec, *, verbose: bool = False,
            obs: Any = None) -> Plan:
    """Validate ``spec`` and build the runtime bundle (model, data, mesh)."""
    spec.validate()
    m = spec.num_workers

    model, batch_fn, eval_fn = _build_model_and_data(spec)

    mesh = None
    if spec.mesh:
        from repro.launch.mesh import make_host_mesh
        d, mm = parse_mesh(spec.mesh)
        mesh = make_host_mesh(data=d, model=mm)

    opt_cfg = spec.opt
    if spec.schedule:
        from repro.optim import schedules
        params = dict(spec.schedule_params)
        if spec.schedule in ("cosine_decay", "warmup_cosine"):
            params.setdefault("total_steps", spec.steps)
        fn = getattr(schedules, spec.schedule)
        opt_cfg = dataclasses.replace(
            opt_cfg, lr=fn(float(spec.opt.lr), **params))

    telemetry = spec.telemetry_path or (
        spec.defense.telemetry_path if spec.defense is not None else None)

    return Plan(
        spec=spec,
        topology=spec.topology,
        topology_params=dict(spec.topology_params),
        model=model,
        batch_fn=batch_fn,
        eval_fn=eval_fn,
        robust_cfg=spec.effective_robust(),
        opt_cfg=opt_cfg,
        defense_cfg=spec.defense,
        mesh=mesh,
        num_workers=m,
        steps=spec.steps,
        seed=spec.seed,
        record_every=spec.record_every(),
        checkpoint_path=spec.checkpoint_path or None,
        checkpoint_every=spec.checkpoint_every,
        telemetry_path=telemetry or None,
        verbose=verbose,
        obs=obs,
    )


def run_experiment(spec: ScenarioSpec, *, verbose: bool = False,
                   obs: Any = None) -> ExperimentResult:
    """THE training entry point: validate + resolve ``spec``, dispatch to
    its topology plugin, return the :class:`ExperimentResult`.

    ``obs`` (a ``repro.obs.ObsConfig`` or None) arms the metrics registry
    and span tracer for this run; the launch CLIs map their ``--metrics``/
    ``--profile-dir`` flags onto it."""
    plan = resolve(spec, verbose=verbose, obs=obs)
    return make_topology(plan.topology).run(plan)


def _build_model_and_data(spec: ScenarioSpec):
    """(model, batch_fn, eval_fn) for the spec's model × data cell."""
    m, ds = spec.model, spec.data
    global_batch = spec.num_workers * ds.batch_per_worker

    if m.kind == "arch":
        from repro.configs import get_arch
        from repro.models import build_model
        cfg = get_arch(m.arch)
        model = build_model(cfg, remat=m.remat)
        from repro.data.pipeline import TokenStream
        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=ds.seq_len,
                             global_batch=global_batch, seed=ds.seed)
        return model, stream.batch, None

    from repro.data.pipeline import ClassificationData
    data = ClassificationData(num_classes=ds.num_classes, dim=ds.dim,
                              noise=ds.noise, seed=ds.seed)

    if m.kind == "cnn":
        from repro.models.cnn import build_cnn_model, cnn_topk_accuracy
        size, ch = m.cnn_size, m.cnn_channels
        model = build_cnn_model(in_ch=ch, size=size)
        reshape = lambda x: x.reshape(-1, size, size, ch)  # noqa: E731
        test = data.test_set(1024)
        test = {"x": reshape(test["x"]), "y": test["y"]}

        def batch_fn(step: int) -> dict:
            raw = data.batch(step, global_batch)
            return {"x": reshape(raw["x"]), "y": raw["y"]}

        return model, batch_fn, lambda p: cnn_topk_accuracy(p, test, k=3)

    from repro.models.mlp import build_mlp_model, mlp_accuracy
    dims = m.dims or (ds.dim, 128, 128, ds.num_classes)
    model = build_mlp_model(dims=dims)
    test = data.test_set(1024)
    return (model, lambda step: data.batch(step, global_batch),
            lambda p: mlp_accuracy(p, test))


def plan_from_parts(*, model, batch_fn, robust_cfg, opt_cfg,
                    num_workers: int, steps: int, seed: int = 0,
                    topology: str = "sync_ps",
                    topology_params: Optional[dict] = None,
                    eval_fn=None, defense_cfg=None, mesh=None,
                    record_every: int = 10,
                    checkpoint_path: Optional[str] = None,
                    checkpoint_every: int = 0,
                    telemetry_path: Optional[str] = None,
                    verbose: bool = False, obs: Any = None) -> Plan:
    """Build a :class:`Plan` from already-constructed runtime objects.

    The deprecated driver shims use this: they hold a live model/batch_fn
    rather than a declarative spec, so they skip spec resolution and enter
    the shared topology loops directly (``spec=None`` on the result)."""
    return Plan(
        spec=None, topology=topology,
        topology_params=dict(topology_params or {}),
        model=model, batch_fn=batch_fn, eval_fn=eval_fn,
        robust_cfg=robust_cfg, opt_cfg=opt_cfg, defense_cfg=defense_cfg,
        mesh=mesh, num_workers=num_workers, steps=steps, seed=seed,
        record_every=max(record_every, 1),
        checkpoint_path=checkpoint_path, checkpoint_every=checkpoint_every,
        telemetry_path=telemetry_path, verbose=verbose, obs=obs)
