"""Phocas reproduction package.

Importing any ``repro.*`` module installs the jax-version compat shims
(``repro.dist.compat``): the codebase and its tests target the modern jax
sharding surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``/``get_abstract_mesh``), back-filled onto the
pinned 0.4-era jax.  The install is attribute-level and touches no jax
device state, so import order vs. XLA_FLAGS does not matter.
"""
from repro.dist import compat as _compat

_compat.install()
del _compat
